"""``shard-coverage`` — every logical axis name the models emit must be
resolvable by every serving rule table.

``spec_for`` silently replicates a logical axis it has no rule for, so
a new mixer family (or a renamed axis) can quietly turn a sharded
dimension into a replicated one on the whole fleet.  This probe walks
``param_axes`` / ``cache_axes`` / ``paged_cache_axes`` for every config
in ``configs/`` and fails on any axis name missing from any rule set in
``sharding.RULE_SETS``.
"""
from __future__ import annotations

from typing import List, Set

from ..report import Finding

PROBE_ID = "shard-coverage"

_SHARDING_PATH = "src/repro/distributed/sharding.py"


def _axis_names(tree) -> Set[str]:
    names: Set[str] = set()

    def walk(node) -> None:
        if isinstance(node, str):
            names.add(node)
        elif isinstance(node, (list, tuple)):
            for item in node:
                walk(item)
        elif isinstance(node, dict):
            for item in node.values():
                walk(item)
        elif hasattr(node, "__dataclass_fields__"):
            for f in node.__dataclass_fields__:
                walk(getattr(node, f))

    walk(tree)
    return names


def check() -> List[Finding]:
    from repro import configs as C
    from repro.distributed import sharding as Sh
    from repro.models import transformer as T

    findings: List[Finding] = []
    for arch in C.ARCH_IDS:
        cfg = C.get_config(arch)  # metadata only: no arrays materialised
        param_names = _axis_names(T.param_axes(cfg))
        act_names = _axis_names(T.cache_axes(cfg)) \
            | _axis_names(T.paged_cache_axes(cfg))
        for rules_name, rules in sorted(Sh.RULE_SETS.items()):
            missing_p = sorted(param_names - set(rules.param_rules))
            missing_a = sorted(act_names - set(rules.act_rules))
            if missing_p:
                findings.append(Finding(
                    PROBE_ID, _SHARDING_PATH, 0,
                    f"{arch}: param logical axes {missing_p} have no rule "
                    f"in {rules_name.upper()}_RULES; spec_for would "
                    "silently replicate them"))
            if missing_a:
                findings.append(Finding(
                    PROBE_ID, _SHARDING_PATH, 0,
                    f"{arch}: cache logical axes {missing_a} have no rule "
                    f"in {rules_name.upper()}_RULES; decode carries would "
                    "silently replicate them"))
    return findings
