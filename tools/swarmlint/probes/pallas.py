"""``pallas-grid`` — every Pallas kernel's block shapes must divide the
geometry of every config the engine can serve.

The kernels tile with ``grid = dim // block``; a block that does not
divide its dimension trips the kernel's divisibility assert on TPU at
the first request with that geometry — long after CI's interpret-mode
parity tests passed on friendlier shapes.  This probe sweeps, for every
config in ``configs/`` (full *and* smoke):

* the decode-attention time tile over every legal cache length (the
  engine grows caches in 64-slot granules, lcm'd with attn_kv_block
  beyond one block);
* the flash-attention (bq, bk) tiles over prompt buckets x cache
  lengths;
* the uncertainty kernel's (bn, bv) tiles over the config's vocabulary
  and serving batch sizes;
* the paged ring constraint: a windowed config's window must be a
  multiple of the pool block length (the ring view is whole blocks).
"""
from __future__ import annotations

import math
from typing import List

from ..report import Finding

PROBE_ID = "pallas-grid"

_BLOCKING_PATH = "src/repro/kernels/blocking.py"
_ENGINE_PATH = "src/repro/serving/engine.py"

# engine geometry: caches grow in 64-slot granules; serve batches are
# small powers of two; probe sweeps beyond the defaults for headroom
_MAX_CACHE_LEN = 4096
_BATCHES = (1, 2, 3, 4, 8, 16)


def _cache_lengths(kv_block: int, block_len: int) -> List[int]:
    """Legal cache lengths: multiples of 64 up to one kv block, then
    multiples of lcm(kv_block, block_len) (mirrors engine._cache_len)."""
    lengths = [n for n in range(64, _MAX_CACHE_LEN + 1, 64)]
    g = math.lcm(kv_block, block_len)
    lengths += [n for n in range(g, 4 * g + 1, g)]
    return sorted(set(lengths))


def check() -> List[Finding]:
    from repro import configs as C
    from repro.kernels import blocking
    from repro.serving import engine as E

    import dataclasses
    block_len = next(f.default for f in dataclasses.fields(E.InferenceEngine)
                     if f.name == "block_len")

    findings: List[Finding] = []
    seen = set()

    def bad(path: str, msg: str) -> None:
        if msg in seen:
            return
        seen.add(msg)
        findings.append(Finding(PROBE_ID, path, 0, msg))

    for arch in C.ARCH_IDS:
        for cfg, is_full in ((C.get_config(arch), True),
                             (C.get_smoke(arch), False)):
            kvb = cfg.attn_kv_block
            for T in _cache_lengths(kvb, block_len):
                bt = blocking.decode_blocks(T)
                if T % bt:
                    bad(_BLOCKING_PATH,
                        f"{arch}: decode tile {bt} does not divide cache "
                        f"length {T}")
                for S in (64, 128, 256, 320, 512, 1024):
                    bq, bk = blocking.flash_blocks(S, T)
                    if S % bq or T % bk:
                        bad(_BLOCKING_PATH,
                            f"{arch}: flash tiles ({bq}, {bk}) do not "
                            f"divide (S={S}, T={T})")
            V = cfg.vocab_size
            for N in _BATCHES:
                bn, bv = blocking.uncertainty_blocks(N, V)
                if N % bn or V % bv:
                    bad(_BLOCKING_PATH,
                        f"{arch}: uncertainty tiles ({bn}, {bv}) do not "
                        f"divide (N={N}, V={V})")
            # smoke configs pick a matching block_len at construction (the
            # engine validates); the DEFAULT block_len must fit full configs
            if is_full and cfg.window is not None and \
                    cfg.window % block_len:
                bad(_ENGINE_PATH,
                    f"{arch}: local-attention window {cfg.window} is not "
                    f"a multiple of pool block_len {block_len}; the paged "
                    "ring view cannot cover it with whole blocks")
    return findings
