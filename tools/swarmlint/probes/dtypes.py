"""``decode-dtype`` — the decode step must not widen its carried state.

Two checks per architecture (smoke config, abstract eval only):

* **carry stability** — ``jax.eval_shape(decode_step)``: every cache
  leaf must come back with the dtype it went in with.  A decode step
  that returns an f32-widened cache doubles resident memory on the
  *second* step and breaks monolithic/paged bitwise parity.
* **no f32 convert of cache-shaped values** — walk the decode jaxpr
  (including sub-jaxprs) for ``convert_element_type`` equations that
  produce float32 from an operand whose shape matches a cache leaf:
  converting the cache itself to f32 mid-step is drift even when the
  final carry dtype is correct.  (Softmax/logit f32 accumulation on
  activation shapes is fine and expected.)
"""
from __future__ import annotations

from typing import List

from ..report import Finding

PROBE_ID = "decode-dtype"

_ENGINE_PATH = "src/repro/serving/engine.py"


def _leaves_with_path(tree):
    import jax
    return jax.tree_util.tree_flatten_with_path(tree)[0]


def _jaxpr_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _jaxpr_eqns(sub)


def _sub_jaxprs(value):
    from jax._src.core import ClosedJaxpr, Jaxpr  # stable across 0.4.x
    if isinstance(value, ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, Jaxpr):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _sub_jaxprs(v)


def check() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import configs as C
    from repro.models import transformer as T

    findings: List[Finding] = []
    B = 2
    for arch in C.ARCH_IDS:
        cfg = C.get_smoke(arch)
        L = 64
        params = T.abstract_params(cfg)
        cache = jax.eval_shape(lambda: T.init_cache(cfg, B, L))
        tok = jax.ShapeDtypeStruct((B, 1), np.int32)
        idx = jax.ShapeDtypeStruct((B,), np.int32)

        def step(p, c, t, i):
            return T.decode_step(p, cfg, t, c, i)

        _, out_cache = jax.eval_shape(step, params, cache, tok, idx)
        in_leaves = _leaves_with_path(cache)
        out_leaves = _leaves_with_path(out_cache)
        for (path_in, leaf_in), (_, leaf_out) in zip(in_leaves, out_leaves):
            if leaf_in.dtype != leaf_out.dtype:
                findings.append(Finding(
                    PROBE_ID, _ENGINE_PATH, 0,
                    f"{arch}: decode_step widens cache leaf "
                    f"{jax.tree_util.keystr(path_in)} from "
                    f"{leaf_in.dtype} to {leaf_out.dtype}"))

        # f32 converts whose operand is cache-shaped
        bf16_shapes = {tuple(l.shape) for _, l in in_leaves
                       if l.dtype == jnp.bfloat16 or l.dtype == cfg.dtype}
        jaxpr = jax.make_jaxpr(step)(params, cache, tok, idx)
        for eqn in _jaxpr_eqns(jaxpr.jaxpr):
            if eqn.primitive.name != "convert_element_type":
                continue
            if eqn.params.get("new_dtype") != jnp.float32:
                continue
            for invar in eqn.invars:
                aval = getattr(invar, "aval", None)
                if aval is not None and tuple(aval.shape) in bf16_shapes \
                        and aval.dtype == cfg.dtype:
                    findings.append(Finding(
                        PROBE_ID, _ENGINE_PATH, 0,
                        f"{arch}: decode jaxpr converts a cache-shaped "
                        f"{aval.dtype}{list(aval.shape)} value to float32"))
                    break
    return findings
