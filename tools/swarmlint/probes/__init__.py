"""Abstract-eval probes: device-free checks that trace/eval-shape the
actual serving entry points over every ModelConfig in ``configs/``.

Unlike the AST rules these import jax and the repro package (CPU
backend, abstract values only — nothing is compiled or executed on an
accelerator), so they catch semantic drift the source-level lints
cannot: a sharding-rule table missing a logical axis some new mixer
introduced, a decode step that silently widens its carried cache, a
donated buffer that stops aliasing, a Pallas block shape that stops
dividing a config's geometry.

Probe findings are not pragma-suppressible: they point at real
config/geometry inconsistencies, not at a line of code that could be
annotated.
"""
from __future__ import annotations

import os
import sys
from typing import List, Optional

from ..report import Finding


def _ensure_imports() -> None:
    """Make ``repro`` importable and force the CPU backend before jax
    initialises (probes must run identically with or without devices)."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    here = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))          # repo root (tools/..)
    src = os.path.join(os.path.dirname(here), "src") \
        if os.path.basename(here) == "tools" else os.path.join(here, "src")
    for p in (src,):
        if os.path.isdir(p) and p not in sys.path:
            sys.path.insert(0, p)


def run_probes(only: Optional[set] = None) -> List[Finding]:
    _ensure_imports()
    from . import donation, dtypes, pallas, sharding
    probes = {
        sharding.PROBE_ID: sharding.check,
        dtypes.PROBE_ID: dtypes.check,
        donation.PROBE_ID: donation.check,
        pallas.PROBE_ID: pallas.check,
    }
    findings: List[Finding] = []
    for probe_id, check in probes.items():
        if only is not None and probe_id not in only:
            continue
        try:
            findings.extend(check())
        except Exception as e:  # a crashing probe is itself a finding
            findings.append(Finding(
                probe_id, f"tools/swarmlint/probes", 0,
                f"probe crashed: {type(e).__name__}: {e}"))
    findings.sort(key=lambda f: (f.rule, f.path, f.line))
    return findings


PROBE_IDS = ("shard-coverage", "decode-dtype", "donation-alias",
             "pallas-grid")
