"""``donation-alias`` — every donated cache buffer must actually alias
an output, exactly once.

``donate_argnames`` is a request, not a guarantee: if a donated leaf's
shape/dtype stops matching any output, XLA silently drops the aliasing
and the paged pool pays a full cache copy per decode chunk.  Lowering
is enough to see the result — donated inputs that alias carry a
``tf.aliasing_output`` attribute in the stablehlo module — so this
probe abstractly lowers the paged serving entry points (tiny smoke
engine, CPU backend, nothing compiled or executed) and checks:

* aliased-parameter count == donated cache leaf count (no dropped
  donations);
* every aliased output index is distinct (a donated buffer aliased
  into two outputs is undefined behaviour).
"""
from __future__ import annotations

import re
from typing import List

from ..report import Finding

PROBE_ID = "donation-alias"

_ENGINE_PATH = "src/repro/serving/engine.py"
_ALIAS_RE = re.compile(r"tf\.aliasing_output\s*=\s*(\d+)")


def check() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import configs as C
    from repro.models import transformer as T
    from repro.serving import engine as E

    findings: List[Finding] = []
    cfg = C.get_smoke("smollm-135m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = E.InferenceEngine("lint", cfg, params, max_len=64, paged=True)

    B, S, max_new = 2, 32, 4
    prompts = np.zeros((B, S), np.int32) + 7
    pb, s_orig = eng._bucket(prompts)
    max_len = eng._cache_len(pb.shape[1], max_new)
    handle = eng.pool.alloc(B, max_len // eng.block_len)
    cache = eng._paged_dev_cache(handle.tables, handle.rows)
    n_donated = len(jax.tree.leaves(cache))
    rng = jax.random.PRNGKey(0)

    lowered = {
        "_generate_fused_paged": E._generate_fused_paged.lower(
            eng.params, cfg, jnp.asarray(pb), jnp.int32(s_orig), cache,
            rng, eng.ucfg, max_new, True, impl=eng.attn_decode_impl,
            mesh=None, rules=eng.rules),
        "_prefill_into_paged": E._prefill_into_paged.lower(
            eng.params, cfg, jnp.asarray(pb), jnp.int32(s_orig), cache,
            mesh=None, rules=eng.rules),
        "_decode_scan_paged": E._decode_scan_paged.lower(
            eng.params, cfg, jnp.zeros((B,), jnp.int32),
            jnp.zeros((B, cfg.vocab_size), jnp.float32), cache,
            jnp.full((B,), s_orig, jnp.int32), rng, eng.ucfg, 4, True,
            impl=eng.attn_decode_impl, mesh=None, rules=eng.rules),
    }
    eng.pool.release(handle)

    for name, low in lowered.items():
        indices = [int(m) for m in _ALIAS_RE.findall(low.as_text())]
        if len(indices) != n_donated:
            findings.append(Finding(
                PROBE_ID, _ENGINE_PATH, 0,
                f"{name}: {len(indices)} of {n_donated} donated cache "
                "leaves alias an output; the rest are silently copied "
                "(shape/dtype mismatch between donated input and result)"))
        dups = sorted({i for i in indices if indices.count(i) > 1})
        if dups:
            findings.append(Finding(
                PROBE_ID, _ENGINE_PATH, 0,
                f"{name}: output indices {dups} are aliased by more than "
                "one donated buffer"))
    return findings
