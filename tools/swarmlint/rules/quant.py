"""``quant-scale-drift`` — quantized-cache scale hygiene.

The quantized serving contract (docs/RUNTIME.md "Quantized caches") has
two invariants this rule guards:

1. **Scales are float32.**  A per-row scale is one number standing in
   for 64-128 mantissas; storing it bf16 injects up to 2^-8 relative
   error into every element of the row and silently widens the
   quantized-vs-bf16 logit budget.  Any "scale"-named allocation or
   cast that lands on a non-f32 floating dtype flags.
2. **Dequantization never materialises f32 cache copies.**  The fused
   decode paths fold scales into the softmax accumulator (which is
   already f32); building a dequantized f32 view of pool-shaped data —
   ``dequantize_rows(..., jnp.float32)``, or a manual
   ``q.astype(jnp.float32) * scale`` multiply — recreates the memory
   traffic quantization exists to remove, 4x the quantized bytes.  The
   gathered-view *oracle* does exactly this on purpose; it carries the
   pragma with its justification.

Scope: ``models/``, ``serving/`` and ``kernels/`` (the serving data
path).  Benchmarks and tests may materialise whatever they like.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from ..astutil import SourceFile, dotted
from ..report import Finding

RULE = "quant-scale-drift"

APPLY_DIRS = ("models", "serving", "kernels")

_ALLOC_FNS = {"zeros", "ones", "empty", "full", "zeros_like", "full_like"}
_DTYPE_POS = {"zeros": 1, "ones": 1, "empty": 1, "full": 2,
              "zeros_like": 1, "full_like": 2}
_F32_NAMES = {"jnp.float32", "np.float32", "numpy.float32",
              "jax.numpy.float32"}
# non-f32 FLOAT dtypes a scale must never take; integer dtypes are left
# to the type checker (a scale as int is a different bug class)
_NARROW_NAMES = {"jnp.bfloat16", "jnp.float16", "jax.numpy.bfloat16",
                 "jax.numpy.float16", "np.float16", "numpy.float16"}


def _is_f32(node: Optional[ast.AST]) -> bool:
    if node is None:
        return False
    if dotted(node) in _F32_NAMES:
        return True
    return isinstance(node, ast.Constant) and node.value == "float32"


def _is_narrow_float(node: Optional[ast.AST]) -> bool:
    if node is None:
        return False
    if dotted(node) in _NARROW_NAMES:
        return True
    return isinstance(node, ast.Constant) and node.value in (
        "bfloat16", "float16")


def _mentions_scale(node: ast.AST) -> bool:
    """Any Name / attribute component containing 'scale' in the subtree."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and "scale" in sub.id.lower():
            return True
        if isinstance(sub, ast.Attribute) and "scale" in sub.attr.lower():
            return True
    return False


def _has_f32_astype(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Call)
                and isinstance(sub.func, ast.Attribute)
                and sub.func.attr == "astype"
                and sub.args and _is_f32(sub.args[0])):
            return True
    return False


def _dtype_arg(call: ast.Call, fn_last: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == "dtype":
            return kw.value
    pos = _DTYPE_POS.get(fn_last)
    if pos is not None and len(call.args) > pos:
        return call.args[pos]
    return None


def check(src: SourceFile) -> List[Finding]:
    parts = src.path.replace("\\", "/").split("/")
    if not any(d in parts for d in APPLY_DIRS):
        return []
    findings: List[Finding] = []

    def emit(node: ast.AST, msg: str) -> None:
        findings.append(Finding(RULE, src.path, node.lineno, msg,
                                node.col_offset))

    for node in ast.walk(src.tree):
        # (A) scale-named allocation with a narrow float dtype
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if any("scale" in t.lower() for t in targets):
                fname = dotted(node.value.func) or ""
                head, _, last = fname.rpartition(".")
                if last in _ALLOC_FNS and head in ("jnp", "jax.numpy",
                                                   "np", "numpy"):
                    dt = _dtype_arg(node.value, last)
                    if _is_narrow_float(dt):
                        emit(node.value,
                             f"scale '{targets[0]}' allocated as a narrow "
                             "float; per-row quant scales must stay "
                             "float32 (one scale stands in for a whole "
                             "row's mantissas)")
        if isinstance(node, ast.Call):
            # (A) scale-named value cast to a narrow float
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype" and node.args
                    and _is_narrow_float(node.args[0])
                    and _mentions_scale(node.func.value)):
                emit(node, "quant scale cast to a narrow float; scales "
                           "must stay float32 end-to-end")
            # (B) materialised f32 dequant of pool/weight rows
            if (dotted(node.func) or "").rpartition(".")[2] \
                    == "dequantize_rows":
                dt = node.args[2] if len(node.args) > 2 else None
                for kw in node.keywords:
                    if kw.arg == "dtype":
                        dt = kw.value
                if _is_f32(dt):
                    emit(node, "dequantize_rows to float32 materialises a "
                               "full-width dequantized copy (4x the "
                               "quantized bytes); dequant to the cache "
                               "dtype, or fold the scale into the f32 "
                               "accumulator instead")
        # (C) manual f32 dequant multiply outside the accumulator
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
            l, r = node.left, node.right
            if ((_has_f32_astype(l) and _mentions_scale(r))
                    or (_has_f32_astype(r) and _mentions_scale(l))):
                emit(node, "f32 .astype multiplied by a scale: a manual "
                           "f32 dequant on cache-shaped data; the fused "
                           "decode paths apply scales inside the softmax "
                           "accumulator instead of widening the rows")
    return findings
