"""``tracer-leak`` — host-Python operations on traced values inside a
jit-decorated body.

Inside ``jax.jit``, a Python ``if``/``while`` on a traced value raises
``TracerBoolConversionError`` at trace time at best, or silently bakes
one branch into the compiled program when the value happens to be
concrete during tracing.  ``int()``/``float()``/``bool()``/``.item()``/
``np.asarray()`` force a device sync (or fail abstractly).  Shape and
dtype inspection (``x.shape``, ``x.ndim``, ``len(x)``) is static and
fine, as is branching on parameters named in ``static_argnames``.
"""
from __future__ import annotations

import ast
from typing import List, Set

from ..astutil import SourceFile, build_jit_registry, dotted
from ..report import Finding

RULE = "tracer-leak"

# attributes of a traced array that are static at trace time
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "weak_type"}
# calls whose result is static regardless of traced inputs
_STATIC_FNS = {"len", "isinstance", "type", "getattr", "hasattr", "id"}
# host-conversion callables that leak a tracer
_LEAK_FNS = {"int", "float", "bool", "complex"}
_LEAK_NP_FNS = {"asarray", "array", "ascontiguousarray"}
_LEAK_METHODS = {"item", "tolist", "__array__"}


class _TaintChecker:
    def __init__(self, path: str, fn: ast.FunctionDef, static: Set[str]):
        self.path = path
        self.fn = fn
        params = [a.arg for a in fn.args.posonlyargs + fn.args.args
                  + fn.args.kwonlyargs]
        self.taint: Set[str] = {p for p in params if p not in static}
        self.findings: List[Finding] = []

    def tainted(self, node: ast.AST) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return isinstance(node.ctx, ast.Load) and node.id in self.taint
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.tainted(node.value)
        if isinstance(node, ast.Call):
            fname = dotted(node.func)
            if fname in _STATIC_FNS:
                return False
            return any(self.tainted(a) for a in node.args) or \
                any(self.tainted(kw.value) for kw in node.keywords) or \
                self.tainted(node.func)
        if isinstance(node, ast.Subscript):
            # x.shape[0] is static; x[0] of a traced x is traced
            return self.tainted(node.value)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return False
        return any(self.tainted(c) for c in ast.iter_child_nodes(node))

    def propagate(self) -> None:
        """Fixed-point taint propagation through simple assignments."""
        for _ in range(4):
            changed = False
            for node in ast.walk(self.fn):
                if isinstance(node, ast.Assign) and self.tainted(node.value):
                    for t in node.targets:
                        for sub in ast.walk(t):
                            if isinstance(sub, ast.Name) and \
                                    sub.id not in self.taint:
                                self.taint.add(sub.id)
                                changed = True
            if not changed:
                return

    def emit(self, node: ast.AST, what: str) -> None:
        self.findings.append(Finding(
            RULE, self.path, node.lineno,
            f"{what} on a traced value inside jitted "
            f"'{self.fn.name}'; hoist it out of the jit or mark the "
            "argument static", getattr(node, "col_offset", 0)))

    def run(self) -> List[Finding]:
        self.propagate()
        for node in ast.walk(self.fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not self.fn:
                continue
            if isinstance(node, (ast.If, ast.While)) and \
                    self.tainted(node.test):
                self.emit(node, "Python `if`/`while` branch")
            elif isinstance(node, ast.Assert) and self.tainted(node.test):
                self.emit(node, "`assert`")
            elif isinstance(node, ast.Call):
                fname = dotted(node.func) or ""
                args_tainted = any(self.tainted(a) for a in node.args)
                if fname in _LEAK_FNS and args_tainted:
                    self.emit(node, f"host conversion `{fname}()`")
                elif fname.rpartition(".")[2] in _LEAK_NP_FNS and \
                        fname.split(".")[0] in ("np", "numpy") and \
                        args_tainted:
                    self.emit(node, f"`{fname}()` host materialisation")
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr in _LEAK_METHODS and \
                        self.tainted(node.func.value):
                    self.emit(node, f"`.{node.func.attr}()` device sync")
        return self.findings


def check(src: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    registry = build_jit_registry(src.tree)
    for spec in registry.values():
        if spec.node is None:
            continue
        findings.extend(
            _TaintChecker(src.path, spec.node, spec.static).run())
    return findings
