"""Donation rules.

``donation-reuse`` — a caller passes a variable into a jit with
``donate_argnames`` covering that parameter, then reads the same
variable again without rebinding it.  On TPU the donated buffer is
aliased into the outputs and invalidated; the reuse returns garbage (or
a deleted-buffer error) that CPU interpret runs never surface.

``donation-dup`` — a jit declaration whose ``donate_argnames`` names a
parameter twice, names a parameter that does not exist, or names one
that is also in ``static_argnames`` (static args have no buffers to
donate; XLA silently ignores the donation and the memory win is lost).
"""
from __future__ import annotations

import ast
from typing import Dict, List

from ..astutil import (JitSpec, SourceFile, StmtSimulator, _jit_call_kwargs,
                       build_jit_registry, dotted, iter_functions)
from ..report import Finding

RULE_REUSE = "donation-reuse"
RULE_DUP = "donation-dup"


class _DonationSim(StmtSimulator):
    """state[name] = ("dead", kill_line, callee) after a donating call."""

    def __init__(self, path: str, fn: ast.FunctionDef,
                 registry: Dict[str, JitSpec]):
        super().__init__(path, fn)
        self.registry = registry

    def on_load(self, name: str, node: ast.AST) -> None:
        st = self.state.get(name)
        if isinstance(st, tuple) and st[0] == "dead":
            self.emit(RULE_REUSE, node.lineno,
                      f"'{name}' was donated to jitted '{st[2]}' on line "
                      f"{st[1]} and is reused here without being rebound; "
                      "the donated buffer is invalid after the call",
                      node.col_offset)

    def on_call(self, call: ast.Call) -> None:
        callee = dotted(call.func)
        spec = self.registry.get(callee) if callee else None
        if spec is None or not spec.donate:
            return
        donated_vars = []
        for i, arg in enumerate(call.args):
            if (isinstance(arg, ast.Name) and i < len(spec.params)
                    and spec.params[i] in spec.donate):
                donated_vars.append(arg.id)
        for kw in call.keywords:
            if isinstance(kw.value, ast.Name) and kw.arg in spec.donate:
                donated_vars.append(kw.value.id)
        for var in donated_vars:
            self.state[var] = ("dead", call.lineno, spec.name)

    def on_store(self, name: str, node: ast.AST) -> None:
        self.state.pop(name, None)


def _donate_list(dec: ast.expr) -> List[str]:
    """donate_argnames as a raw list (duplicates preserved)."""
    if not isinstance(dec, ast.Call):
        return []
    kwargs = _jit_call_kwargs(dec)
    if not kwargs or "donate_argnames" not in kwargs:
        return []
    node = kwargs["donate_argnames"]
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


def check(src: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    registry = build_jit_registry(src.tree)

    # declaration-level checks
    for fn in iter_functions(src.tree):
        spec = registry.get(fn.name)
        if spec is None or spec.node is not fn:
            continue
        raw = []
        for dec in fn.decorator_list:
            raw = _donate_list(dec)
            if raw:
                break
        for name in sorted(set(n for n in raw if raw.count(n) > 1)):
            findings.append(Finding(
                RULE_DUP, src.path, fn.lineno,
                f"'{fn.name}' donates parameter '{name}' more than once"))
        for name in sorted(spec.donate - set(spec.params)):
            findings.append(Finding(
                RULE_DUP, src.path, fn.lineno,
                f"'{fn.name}' donates '{name}' which is not a parameter"))
        for name in sorted(spec.donate & spec.static):
            findings.append(Finding(
                RULE_DUP, src.path, fn.lineno,
                f"'{fn.name}' marks '{name}' both static and donated; "
                "static arguments have no device buffer to donate"))

    # caller-side reuse
    for fn in iter_functions(src.tree):
        findings.extend(_DonationSim(src.path, fn, registry).run())
    return findings
