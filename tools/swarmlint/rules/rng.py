"""RNG discipline rules.

``global-rng`` — no global-state randomness (stdlib ``random.*`` module
draws, ``np.random.*`` module-level draws) in ``serving/`` or
``kernels/``: anything on a hot serving path must draw from an owned,
seeded generator (``np.random.RandomState(seed)`` / jax PRNG keys) so
runs are bitwise reproducible and fault injection replays exactly
(PR 8's "empty FaultPlan draws zero rng" contract).

``key-reuse`` — a jax PRNG key is consumed at most once per binding:
after a key variable is passed into any call it must be rebound
(typically via ``rng, sub = jax.random.split(rng)``) before being
passed again.  Reusing a key correlates streams that must be
independent; the classic failure is passing a live key into a loop body
every iteration.
"""
from __future__ import annotations

import ast
import re
from typing import List, Set, Tuple

from ..astutil import SourceFile, StmtSimulator, dotted, iter_functions
from ..report import Finding

RULE_GLOBAL = "global-rng"
RULE_KEY = "key-reuse"

# directories (path fragments) where global-state randomness is banned
GLOBAL_RNG_DIRS = ("serving", "kernels")

_NP_DRAWS = {
    "seed", "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "uniform", "normal", "standard_normal", "choice",
    "permutation", "shuffle", "beta", "binomial", "poisson", "exponential",
    "gamma", "bytes", "set_state",
}
_STDLIB_DRAWS = {
    "seed", "random", "randint", "randrange", "uniform", "gauss",
    "normalvariate", "choice", "choices", "sample", "shuffle",
    "betavariate", "expovariate", "getrandbits", "triangular",
    "vonmisesvariate", "paretovariate", "setstate",
}

_KEY_PARAM_RE = re.compile(r"^(rng|key|prng_key|.*_rng|.*_key)$")
_KEY_FNS = ("jax.random.PRNGKey", "jax.random.key", "jax.random.fold_in",
            "jax.random.split", "jax.random.clone", "random.PRNGKey",
            "random.fold_in", "random.split")


def _numpy_and_random_aliases(tree: ast.Module) -> Tuple[Set[str], Set[str],
                                                         Set[str]]:
    """(numpy aliases, numpy.random aliases, stdlib random aliases)."""
    np_alias, npr_alias, rand_alias = set(), set(), set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    np_alias.add(a.asname or "numpy")
                elif a.name == "numpy.random":
                    npr_alias.add(a.asname or "numpy.random")
                elif a.name == "random":
                    rand_alias.add(a.asname or "random")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "numpy":
                for a in node.names:
                    if a.name == "random":
                        npr_alias.add(a.asname or "random")
    return np_alias, npr_alias, rand_alias


def _check_global_rng(src: SourceFile) -> List[Finding]:
    parts = src.path.replace("\\", "/").split("/")
    if not any(d in parts for d in GLOBAL_RNG_DIRS):
        return []
    np_alias, npr_alias, rand_alias = _numpy_and_random_aliases(src.tree)
    findings: List[Finding] = []
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        if name is None or "." not in name:
            continue
        head, _, fn = name.rpartition(".")
        hit = (
            (fn in _NP_DRAWS
             and (head in {f"{a}.random" for a in np_alias}
                  or head in npr_alias))
            or (fn in _STDLIB_DRAWS and head in rand_alias)
        )
        if hit:
            findings.append(Finding(
                RULE_GLOBAL, src.path, node.lineno,
                f"global-state random draw '{name}()' on a serving/kernel "
                "path; use a seeded np.random.RandomState / jax PRNG key "
                "owned by the caller", node.col_offset))
    return findings


def _key_births(stmt: ast.stmt) -> Tuple[List[str], List[str]]:
    """(new single-key names, names to stop tracking) for one statement.

    ``ks = jax.random.split(k, n)`` binds an ARRAY of keys — rows are
    consumed individually, so the container itself is exempt."""
    if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
        return [], []
    value, target = stmt.value, stmt.targets[0]
    if not isinstance(value, ast.Call):
        return [], []
    fname = dotted(value.func) or ""
    last = fname.rsplit(".", 1)[-1]
    is_key_fn = fname in _KEY_FNS or (
        "random" in fname and last in ("PRNGKey", "fold_in", "split",
                                       "clone"))
    if not is_key_fn:
        return [], []
    is_split = last == "split"
    if isinstance(target, ast.Name):
        if is_split:
            return [], [target.id]          # key array, rows used one-off
        return [target.id], []
    if isinstance(target, (ast.Tuple, ast.List)) and is_split:
        names = [e.id for e in target.elts if isinstance(e, ast.Name)]
        return names, []
    return [], []


class _KeySim(StmtSimulator):
    """state[name] = 'fresh' | 'consumed@<line>' for tracked key vars."""

    def __init__(self, path: str, fn: ast.FunctionDef):
        super().__init__(path, fn)
        self.tracked: Set[str] = {
            p for p in (a.arg for a in fn.args.args + fn.args.kwonlyargs)
            if _KEY_PARAM_RE.match(p)}
        for p in self.tracked:
            self.state[p] = "fresh"

    def process_stmt(self, stmt: ast.stmt) -> None:
        births, exempt = _key_births(stmt)
        super().process_stmt(stmt)
        for n in births:
            self.tracked.add(n)
            self.state[n] = "fresh"
        for n in exempt:
            self.tracked.discard(n)
            self.state.pop(n, None)

    def on_call(self, call: ast.Call) -> None:
        args = list(call.args) + [kw.value for kw in call.keywords]
        for arg in args:
            if not (isinstance(arg, ast.Name) and arg.id in self.tracked):
                continue
            st = self.state.get(arg.id, "fresh")
            if isinstance(st, str) and st.startswith("consumed@"):
                prev = st.split("@", 1)[1]
                self.emit(RULE_KEY, call.lineno,
                          f"PRNG key '{arg.id}' passed to a call here but "
                          f"already consumed at line {prev} without being "
                          "split or rebound (possible cross-iteration "
                          "reuse); use jax.random.split",
                          call.col_offset)
            else:
                self.state[arg.id] = f"consumed@{call.lineno}"

    def on_store(self, name: str, node: ast.AST) -> None:
        if name in self.tracked:
            self.state[name] = "fresh"


def check(src: SourceFile) -> List[Finding]:
    findings = _check_global_rng(src)
    for fn in iter_functions(src.tree):
        findings.extend(_KeySim(src.path, fn).run())
    return findings
