"""AST rule registry: stdlib-ast lints that run file-by-file with no
jax import and no devices."""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from ..astutil import SourceFile, iter_py_files
from ..pragmas import PragmaMap
from ..report import Finding
from . import donation, dtype, quant, rng, tracer

# rule-id -> module; a module's check(SourceFile) may emit several ids
AST_RULE_IDS: Dict[str, object] = {
    donation.RULE_REUSE: donation,
    donation.RULE_DUP: donation,
    rng.RULE_GLOBAL: rng,
    rng.RULE_KEY: rng,
    tracer.RULE: tracer,
    dtype.RULE: dtype,
    quant.RULE: quant,
}

_CHECKERS = (donation.check, rng.check, tracer.check, dtype.check,
             quant.check)


def run_ast_rules(paths: Iterable[str],
                  only: Optional[set] = None) -> List[Finding]:
    """Run every AST rule over every .py file under ``paths``; apply
    pragmas; return all findings (suppressed ones marked)."""
    findings: List[Finding] = []
    for root in paths:
        for path in iter_py_files(root):
            try:
                src = SourceFile.load(path)
            except SyntaxError as e:
                findings.append(Finding(
                    "parse-error", path, e.lineno or 0, str(e.msg)))
                continue
            file_findings: List[Finding] = []
            for checker in _CHECKERS:
                file_findings.extend(checker(src))
            if only is not None:
                file_findings = [f for f in file_findings if f.rule in only]
            findings.extend(PragmaMap(path, src.text).apply(file_findings))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
