"""``dtype-drift`` — float32 creeping into bf16-resident cache/state.

The serving stack keeps every long-lived cache (KV blocks, recurrent
state, pool arrays) in ``cfg.dtype`` (bfloat16 by default); a cache
initialiser that allocates ``float32`` — explicitly, or implicitly by
omitting the dtype so jnp defaults to f32 — doubles resident cache
memory and silently changes decode numerics when the state round-trips
through f32.  Intentional f32 accumulators (recurrences that drift in
bf16) carry a pragma with the justification.

Scope: functions whose name marks them as cache/state initialisers
(``init_*``, ``grow_*``, ``*_carry``) in ``models/``, ``serving/`` and
``kernels/``.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional

from ..astutil import SourceFile, dotted, iter_functions
from ..report import Finding

RULE = "dtype-drift"

APPLY_DIRS = ("models", "serving", "kernels")
_INIT_RE = re.compile(r"^(init_|grow_)|_carry$|_init$")
_ALLOC_FNS = {"zeros", "ones", "empty", "full", "zeros_like", "full_like"}
_F32_NAMES = {"jnp.float32", "np.float32", "numpy.float32",
              "jax.numpy.float32"}
# positional index of the dtype argument per constructor
_DTYPE_POS = {"zeros": 1, "ones": 1, "empty": 1, "full": 2,
              "zeros_like": 1, "full_like": 2}


def _is_f32_literal(node: ast.AST) -> bool:
    name = dotted(node)
    if name in _F32_NAMES:
        return True
    return isinstance(node, ast.Constant) and node.value == "float32"


def _dtype_arg(call: ast.Call, fn_last: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == "dtype":
            return kw.value
    pos = _DTYPE_POS.get(fn_last)
    if pos is not None and len(call.args) > pos:
        return call.args[pos]
    return None


def check(src: SourceFile) -> List[Finding]:
    parts = src.path.replace("\\", "/").split("/")
    if not any(d in parts for d in APPLY_DIRS):
        return []
    findings: List[Finding] = []
    for fn in iter_functions(src.tree):
        if not _INIT_RE.search(fn.name):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            fname = dotted(node.func) or ""
            head, _, last = fname.rpartition(".")
            if last in _ALLOC_FNS and head in ("jnp", "jax.numpy", "np",
                                               "numpy"):
                dt = _dtype_arg(node, last)
                if dt is None and not last.endswith("_like"):
                    findings.append(Finding(
                        RULE, src.path, node.lineno,
                        f"'{fn.name}' allocates with `{fname}` and no "
                        "dtype; jnp defaults to float32 — pass cfg.dtype "
                        "(or an explicit integer dtype)",
                        node.col_offset))
                elif dt is not None and _is_f32_literal(dt):
                    findings.append(Finding(
                        RULE, src.path, node.lineno,
                        f"'{fn.name}' allocates cache/state as literal "
                        "float32; caches live in cfg.dtype (bf16) — f32 "
                        "doubles resident cache memory",
                        node.col_offset))
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "astype" and node.args and \
                    _is_f32_literal(node.args[0]):
                findings.append(Finding(
                    RULE, src.path, node.lineno,
                    f"'{fn.name}' widens cache/state to float32 via "
                    ".astype", node.col_offset))
    return findings
