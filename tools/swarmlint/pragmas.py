"""``# swarmlint: ignore[rule-id] <justification>`` pragma handling.

A pragma suppresses findings for the named rule(s) on its own line, or —
when it is a standalone comment line — on the next non-comment line.
The justification text after the bracket is MANDATORY: a pragma without
one does not suppress anything and instead raises a ``bad-pragma``
finding, so every suppression in the tree documents *why* the invariant
is intentionally broken there.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

from .report import Finding

PRAGMA_RE = re.compile(
    r"#\s*swarmlint:\s*ignore\[([a-zA-Z0-9_,\s-]*)\]\s*(.*)$")

# rule-id for a malformed pragma; not itself suppressible.
BAD_PRAGMA = "bad-pragma"


class PragmaMap:
    """Per-file map of line -> set of suppressed rule ids."""

    def __init__(self, path: str, text: str):
        self.path = path
        # line (1-based) -> {rule ids suppressed on that line}
        self.by_line: Dict[int, set] = {}
        self.errors: List[Finding] = []
        self._parse(text)

    def _parse(self, text: str) -> None:
        lines = text.splitlines()
        for i, raw in enumerate(lines, start=1):
            m = PRAGMA_RE.search(raw)
            if m is None:
                if "swarmlint" in raw and "#" in raw.split("swarmlint")[0]:
                    self.errors.append(Finding(
                        BAD_PRAGMA, self.path, i,
                        "unparseable swarmlint pragma (expected "
                        "'# swarmlint: ignore[rule-id] justification')"))
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            justification = m.group(2).strip()
            if not rules:
                self.errors.append(Finding(
                    BAD_PRAGMA, self.path, i,
                    "pragma names no rule ids: ignore[] is empty"))
                continue
            if not justification:
                self.errors.append(Finding(
                    BAD_PRAGMA, self.path, i,
                    f"pragma ignore[{', '.join(sorted(rules))}] has no "
                    "justification text; say why the invariant is "
                    "intentionally broken here"))
                continue
            target = i
            # a standalone comment line applies to the next line of code
            # (skipping continuation comment lines and blanks)
            if raw.strip().startswith("#"):
                target = i + 1
                while target <= len(lines) and (
                        not lines[target - 1].strip()
                        or lines[target - 1].strip().startswith("#")):
                    target += 1
            self.by_line.setdefault(target, set()).update(rules)
            self._just = getattr(self, "_just", {})
            self._just[(target, frozenset(rules))] = justification

    def suppresses(self, rule: str, line: int) -> Tuple[bool, str]:
        """Return (suppressed?, justification) for a finding."""
        rules = self.by_line.get(line, set())
        if rule in rules:
            for (tline, rset), just in getattr(self, "_just", {}).items():
                if tline == line and rule in rset:
                    return True, just
            return True, ""
        return False, ""

    def apply(self, findings: List[Finding]) -> List[Finding]:
        """Mark findings covered by a pragma; append pragma errors."""
        for f in findings:
            if f.rule == BAD_PRAGMA:
                continue
            hit, just = self.suppresses(f.rule, f.line)
            if hit:
                f.suppressed = True
                f.justification = just
        return findings + self.errors
