"""CLI: ``python -m tools.swarmlint [paths...] [options]``.

Exit status: 0 when no active (unsuppressed) findings; 1 otherwise.
``--strict`` additionally fails on malformed pragmas and on suppressed
findings whose rule id no longer exists (stale pragmas).
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    # run from the repo root regardless of invocation cwd, and make the
    # serving stack importable for the probes
    repo = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    os.chdir(repo)
    src = os.path.join(repo, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from tools.swarmlint import run_all
    from tools.swarmlint.probes import PROBE_IDS
    from tools.swarmlint.report import render_json, render_text
    from tools.swarmlint.rules import AST_RULE_IDS

    parser = argparse.ArgumentParser(
        prog="python -m tools.swarmlint",
        description="JAX/Pallas-aware static analysis for the SWARM-LLM "
                    "serving stack")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files/dirs to lint (default: src/repro)")
    parser.add_argument("--strict", action="store_true",
                        help="exit non-zero on any active finding "
                             "(including bad pragmas)")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="machine-readable output")
    parser.add_argument("--no-probes", action="store_true",
                        help="AST rules only (fast, no jax import)")
    parser.add_argument("--rule", action="append", default=None,
                        help="restrict to the given rule id(s)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print known rule ids and exit")
    parser.add_argument("--show-suppressed", action="store_true",
                        help="include pragma-suppressed findings in text "
                             "output")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rid in sorted(AST_RULE_IDS):
            print(f"{rid}  (ast)")
        for rid in PROBE_IDS:
            print(f"{rid}  (probe)")
        return 0

    only = set(args.rule) if args.rule else None
    findings = run_all(args.paths or None,
                       with_probes=not args.no_probes, only=only)
    active = [f for f in findings if not f.suppressed]

    if args.as_json:
        print(render_json(findings))
    else:
        text = render_text(findings, show_suppressed=args.show_suppressed)
        if text:
            print(text)
        n_sup = len(findings) - len(active)
        print(f"swarmlint: {len(active)} finding(s), "
              f"{n_sup} suppressed by pragma")

    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
