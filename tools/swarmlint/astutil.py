"""Shared AST machinery for swarmlint rules.

Provides source loading, a registry of jit-wrapped functions (with their
``donate_argnames`` / ``static_argnames``), dotted-name helpers, and a
small statement-order dataflow simulator that rules subclass to track
"this variable died / was consumed at line N" facts.

The simulator is deliberately an over-approximation tuned for zero
false positives on idiomatic JAX code rather than completeness:

* statements are processed in source order; loads in a statement are
  seen before the statement's own calls take effect, and assignment
  targets are processed last — so ``cur, cache = f(cache)`` (the
  donate-and-rebind idiom) and ``rng, sub = jax.random.split(rng)``
  (the consume-and-rebind idiom) never flag;
* ``if``/``else`` branches merge optimistically (a variable is only
  dead after the branch if it is dead on *both* paths);
* loop bodies are simulated twice, which is what catches
  cross-iteration reuse (a key consumed in iteration ``i`` and again in
  ``i+1`` without a rebind).
"""
from __future__ import annotations

import ast
import copy
import dataclasses
import os
from typing import Dict, Iterator, List, Optional, Set

from .report import Finding


# ---------------------------------------------------------------------------
# source files


@dataclasses.dataclass
class SourceFile:
    path: str
    text: str
    tree: ast.Module

    @classmethod
    def load(cls, path: str) -> "SourceFile":
        with open(path, "r", encoding="utf-8") as fh:
            text = fh.read()
        return cls(path=path, text=text, tree=ast.parse(text, filename=path))


def iter_py_files(root: str) -> Iterator[str]:
    if os.path.isfile(root):
        yield root
        return
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__", ".git"))
        for name in sorted(filenames):
            if name.endswith(".py"):
                yield os.path.join(dirpath, name)


# ---------------------------------------------------------------------------
# name helpers


def dotted(node: ast.AST) -> Optional[str]:
    """Render ``a.b.c`` attribute/name chains; None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def str_const_set(node: Optional[ast.AST]) -> Set[str]:
    """Extract {'a', 'b'} from 'a', ('a', 'b') or ['a', 'b'] literals."""
    if node is None:
        return set()
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        out = set()
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.add(elt.value)
        return out
    return set()


def param_names(fn: ast.FunctionDef) -> List[str]:
    args = fn.args
    names = [a.arg for a in args.posonlyargs] + [a.arg for a in args.args]
    if args.vararg:
        names.append(args.vararg.arg)
    names += [a.arg for a in args.kwonlyargs]
    if args.kwarg:
        names.append(args.kwarg.arg)
    return names


def iter_functions(tree: ast.Module) -> Iterator[ast.FunctionDef]:
    """All function defs in the module, including nested and methods."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


# ---------------------------------------------------------------------------
# jit registry


@dataclasses.dataclass
class JitSpec:
    name: str
    params: List[str]
    donate: Set[str]
    static: Set[str]
    node: Optional[ast.FunctionDef]
    line: int


def _jit_call_kwargs(call: ast.Call) -> Optional[dict]:
    """If ``call`` is jax.jit(...) or partial(jax.jit, ...), return its
    keyword nodes; None otherwise."""
    fname = dotted(call.func)
    if fname in ("jax.jit", "jit"):
        return {kw.arg: kw.value for kw in call.keywords if kw.arg}
    if fname in ("partial", "functools.partial") and call.args:
        inner = dotted(call.args[0])
        if inner in ("jax.jit", "jit"):
            return {kw.arg: kw.value for kw in call.keywords if kw.arg}
    return None


def build_jit_registry(tree: ast.Module) -> Dict[str, JitSpec]:
    """Map function name -> JitSpec for every jit-wrapped function in a
    module: decorator style (``@jax.jit`` / ``@partial(jax.jit, ...)``)
    and assignment style (``f = jax.jit(g, ...)``)."""
    registry: Dict[str, JitSpec] = {}
    defs = {fn.name: fn for fn in iter_functions(tree)}

    for fn in iter_functions(tree):
        for dec in fn.decorator_list:
            kwargs = None
            if isinstance(dec, ast.Call):
                kwargs = _jit_call_kwargs(dec)
            elif dotted(dec) in ("jax.jit", "jit"):
                kwargs = {}
            if kwargs is None:
                continue
            registry[fn.name] = JitSpec(
                name=fn.name, params=param_names(fn),
                donate=str_const_set(kwargs.get("donate_argnames")),
                static=str_const_set(kwargs.get("static_argnames")),
                node=fn, line=fn.lineno)
            break

    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)):
            continue
        call = node.value
        if dotted(call.func) not in ("jax.jit", "jit") or not call.args:
            continue
        inner = dotted(call.args[0])
        kwargs = {kw.arg: kw.value for kw in call.keywords if kw.arg}
        inner_def = defs.get(inner) if inner else None
        registry[node.targets[0].id] = JitSpec(
            name=node.targets[0].id,
            params=param_names(inner_def) if inner_def else [],
            donate=str_const_set(kwargs.get("donate_argnames")),
            static=str_const_set(kwargs.get("static_argnames")),
            node=inner_def, line=node.lineno)
    return registry


# ---------------------------------------------------------------------------
# statement-order dataflow simulator


class StmtSimulator:
    """Walk one function body in statement order with two-pass loops.

    Subclasses override ``on_load`` / ``on_call`` / ``on_store`` and
    mutate ``self.state`` (a dict name -> anything).  Findings are
    deduplicated by (rule, line, message)."""

    def __init__(self, path: str, fn: ast.FunctionDef):
        self.path = path
        self.fn = fn
        self.state: Dict[str, object] = {}
        self.findings: List[Finding] = []
        self._seen: Set[tuple] = set()

    # -- hooks ---------------------------------------------------------
    def on_load(self, name: str, node: ast.AST) -> None: ...
    def on_call(self, call: ast.Call) -> None: ...
    def on_store(self, name: str, node: ast.AST) -> None: ...

    def merge(self, a: Dict[str, object],
              b: Dict[str, object]) -> Dict[str, object]:
        """Optimistic branch merge: keep facts only where both agree."""
        return {k: v for k, v in a.items() if b.get(k) == v}

    # -- emission ------------------------------------------------------
    def emit(self, rule: str, line: int, message: str, col: int = 0) -> None:
        key = (rule, line, message)
        if key in self._seen:
            return
        self._seen.add(key)
        self.findings.append(Finding(rule, self.path, line, message, col))

    # -- traversal -----------------------------------------------------
    def run(self) -> List[Finding]:
        self.process_block(self.fn.body)
        return self.findings

    def _expr_parts(self, node: Optional[ast.AST]):
        """Yield (kind, payload) events for an expression subtree in a
        stable order: loads first, then calls (innermost first)."""
        if node is None:
            return [], []
        loads, calls = [], []
        for sub in ast.walk(node):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                continue
            if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
                loads.append(sub)
            elif isinstance(sub, ast.Call):
                calls.append(sub)
        return loads, calls

    def _eval_expr(self, node: Optional[ast.AST]) -> None:
        loads, calls = self._expr_parts(node)
        for n in loads:
            self.on_load(n.id, n)
        for c in calls:
            self.on_call(c)

    def _store_targets(self, target: ast.AST) -> None:
        for sub in ast.walk(target):
            if isinstance(sub, ast.Name) and isinstance(
                    sub.ctx, (ast.Store, ast.Del)):
                self.on_store(sub.id, sub)

    def process_block(self, stmts: List[ast.stmt]) -> None:
        for stmt in stmts:
            self.process_stmt(stmt)

    def process_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # separate scope; analyzed on its own
        if isinstance(stmt, ast.Assign):
            self._eval_expr(stmt.value)
            for t in stmt.targets:
                self._store_targets(t)
        elif isinstance(stmt, ast.AugAssign):
            self._eval_expr(stmt.value)
            self._eval_expr(stmt.target)
            self._store_targets(stmt.target)
        elif isinstance(stmt, ast.AnnAssign):
            self._eval_expr(stmt.value)
            if stmt.value is not None:
                self._store_targets(stmt.target)
        elif isinstance(stmt, (ast.Expr, ast.Return)):
            self._eval_expr(stmt.value)
        elif isinstance(stmt, ast.If):
            self._eval_expr(stmt.test)
            before = copy.deepcopy(self.state)
            self.process_block(stmt.body)
            after_body = self.state
            self.state = before
            self.process_block(stmt.orelse)
            self.state = self.merge(after_body, self.state)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._eval_expr(stmt.iter)
            self._store_targets(stmt.target)
            entry = copy.deepcopy(self.state)
            for _ in range(2):  # two passes: catch cross-iteration reuse
                self.process_block(stmt.body)
                self._store_targets(stmt.target)
            self.state = self.merge(entry, self.state)
            self.process_block(stmt.orelse)
        elif isinstance(stmt, ast.While):
            entry = copy.deepcopy(self.state)
            for _ in range(2):
                self._eval_expr(stmt.test)
                self.process_block(stmt.body)
            self.state = self.merge(entry, self.state)
            self.process_block(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._eval_expr(item.context_expr)
                if item.optional_vars is not None:
                    self._store_targets(item.optional_vars)
            self.process_block(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.process_block(stmt.body)
            for handler in stmt.handlers:
                self.process_block(handler.body)
            self.process_block(stmt.orelse)
            self.process_block(stmt.finalbody)
        elif isinstance(stmt, (ast.Raise, ast.Assert)):
            self._eval_expr(getattr(stmt, "exc", None)
                            or getattr(stmt, "test", None))
        elif isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                self._store_targets(t)
        # Pass/Break/Continue/Import/Global/Nonlocal: nothing to do
