"""Finding model and renderers for swarmlint.

A ``Finding`` is one violation at one source location.  Findings are
plain data so the CLI can render them as human-readable text or as
machine-readable JSON (``--json``), and so tests can assert on them
structurally instead of scraping output.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable


@dataclasses.dataclass
class Finding:
    rule: str                 # rule id, e.g. "donation-reuse"
    path: str                 # file the violation lives in
    line: int                 # 1-based line number
    message: str              # human-readable description
    col: int = 0              # 0-based column offset
    suppressed: bool = False  # True when an ignore[] pragma covers it
    justification: str = ""   # the pragma's justification text, if any

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def render_text(findings: Iterable[Finding], *,
                show_suppressed: bool = False) -> str:
    lines = []
    for f in findings:
        if f.suppressed and not show_suppressed:
            continue
        tag = " (suppressed)" if f.suppressed else ""
        lines.append(f"{f.location()}: {f.rule}: {f.message}{tag}")
    return "\n".join(lines)


def render_json(findings: Iterable[Finding]) -> str:
    fs = list(findings)
    active = [f for f in fs if not f.suppressed]
    return json.dumps({
        "findings": [f.to_dict() for f in fs],
        "counts": {
            "total": len(fs),
            "active": len(active),
            "suppressed": len(fs) - len(active),
        },
    }, indent=2)
