"""swarmlint: repo-specific static analysis for the SWARM-LLM serving
stack.

Two layers (see docs/STATIC_ANALYSIS.md for the rule catalogue):

* **AST rules** (stdlib ``ast``, no jax import): donation-reuse,
  donation-dup, global-rng, key-reuse, tracer-leak, dtype-drift.
* **Abstract-eval probes** (jax on the CPU backend, nothing executed
  on an accelerator): shard-coverage, decode-dtype, donation-alias,
  pallas-grid.

Entry point: ``python -m tools.swarmlint [--strict] [--json]``.
"""
from __future__ import annotations

from typing import List, Optional

from .report import Finding, render_json, render_text


def run_all(paths: Optional[List[str]] = None, *,
            with_probes: bool = True,
            only: Optional[set] = None) -> List[Finding]:
    from .probes import run_probes
    from .rules import run_ast_rules

    findings = run_ast_rules(paths or ["src/repro"], only=only)
    if with_probes:
        findings.extend(run_probes(only=only))
    return findings


__all__ = ["Finding", "render_json", "render_text", "run_all"]
