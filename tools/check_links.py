#!/usr/bin/env python
"""Check that intra-repo markdown links resolve.

Scans every ``*.md`` file in the repo (skipping dot-dirs and
``experiments/``) for inline links ``[text](target)`` and verifies that
relative targets exist on disk.  External (``http(s)://``, ``mailto:``)
links and pure in-page anchors (``#...``) are ignored; a relative target's
``#anchor`` suffix is stripped before the existence check.

Exit code 0 when every link resolves, 1 otherwise (one line per broken
link) — wired as the ``docs`` job in .github/workflows/ci.yml so the
docs/ tree can't silently rot.

  python tools/check_links.py [repo_root]
"""

from __future__ import annotations

import os
import re
import sys

# inline links, excluding images' src duplication concerns: [text](target)
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "ftp://")
_SKIP_DIRS = {".git", ".github", "experiments", "__pycache__",
              ".pytest_cache"}


def md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if d not in _SKIP_DIRS and not d.startswith(".")]
        for f in filenames:
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def check_file(path: str, root: str) -> list[str]:
    broken = []
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    # drop fenced code blocks: links inside ``` are examples, not links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP_PREFIXES) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        base = root if rel.startswith("/") else os.path.dirname(path)
        resolved = os.path.normpath(os.path.join(base, rel.lstrip("/")))
        if not os.path.exists(resolved):
            broken.append(f"{os.path.relpath(path, root)}: broken link "
                          f"-> {target}")
    return broken


def main() -> int:
    root = os.path.abspath(sys.argv[1] if len(sys.argv) > 1
                           else os.path.join(os.path.dirname(__file__), ".."))
    broken = []
    n = 0
    for path in md_files(root):
        n += 1
        broken += check_file(path, root)
    for line in broken:
        print(line)
    print(f"[check_links] {n} markdown files, {len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main())
