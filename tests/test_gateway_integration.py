"""Gateway integration tests: Algorithm 1 end-to-end with quickly-trained
tiers, fault injection (O5 chain), budget caps, quorum straggler mitigation.

Kept fast: short training (the routing logic under test doesn't need
memorised facts; accuracy-level behaviour is covered by benchmarks/tables).
"""

import numpy as np
import pytest

from repro.core.cost_model import LatencyParams
from repro.core.router import CLOUD, CLOUD_SAFETY, LOCAL, REFUSE, SWARM
from repro.data.workload import FactWorld
from repro.serving.simulator import NetworkSimulator, SimConfig


@pytest.fixture(scope="module")
def system():
    from repro.launch.serve import build_gateway
    gw, probe, cloud, world = build_gateway(train_steps=40, calibrate=True)
    return gw, probe, cloud, world


def _fresh_sim(gw, **kw):
    gw.sim = NetworkSimulator(SimConfig(**kw), LatencyParams(),
                              n_members=len(gw.swarm.members))
    return gw


def test_decisions_are_valid_codes(system):
    gw, _, _, world = system
    log = gw.answer_batch(world.study_workload(6, 6, 4))
    assert set(np.unique(log.decision)) <= {LOCAL, SWARM, CLOUD,
                                            CLOUD_SAFETY, REFUSE}
    assert log.latency.min() > 0
    assert len(log.category) == 16


def test_safety_queries_escalate_or_refuse(system):
    gw, _, _, world = system
    qs = world.safety_queries(8, borderline_frac=0.0)
    log = gw.answer_batch(qs)
    assert np.isin(log.decision, (CLOUD_SAFETY, REFUSE)).mean() >= 0.75


def test_wan_outage_degrades_gracefully(system):
    """O5: cloud -> swarm -> local, never crash, no cloud decisions."""
    gw, _, _, world = system
    gw = _fresh_sim(gw, wan_outage_p=1.0, wan_recover_p=0.0)
    log = gw.answer_batch(world.study_workload(4, 4, 2))
    cloud_mask = np.isin(log.decision, (CLOUD, CLOUD_SAFETY))
    assert not cloud_mask.any()
    assert np.isin(log.decision, (LOCAL, SWARM, REFUSE)).all()
    _fresh_sim(gw)


def test_budget_cap_blocks_cloud(system):
    gw, _, _, world = system
    gw = _fresh_sim(gw)
    old_total = gw.budget.total
    import repro.core.budget as B
    gw.budget = B.init_budget(0.0)
    log = gw.answer_batch(world.study_workload(4, 4, 2))
    assert not np.isin(log.decision, (CLOUD, CLOUD_SAFETY)).any()
    gw.budget = B.init_budget(float(old_total))


def test_node_failure_swarm_still_answers(system):
    gw, _, _, world = system
    gw = _fresh_sim(gw, node_fail_p=1.0, node_recover_p=0.0)
    gw.sim.tick()
    assert not gw.sim.member_up.any()
    log = gw.answer_batch(world.study_workload(4, 4, 0))
    assert len(log.decision) == 8          # answers produced regardless
    _fresh_sim(gw)


def test_quorum_reduces_swarm_tail_latency(system):
    """Beyond-paper straggler mitigation: quorum-k <= full-swarm latency."""
    from repro.core import cost_model as cm
    lat = LatencyParams(agg_overhead=0.0)
    rng = np.random.RandomState(0)
    edge = rng.rand(200, 3) + 0.5
    comm = rng.rand(200, 3) * 0.2
    import jax.numpy as jnp
    full = np.asarray(cm.latency_swarm(jnp.asarray(edge), jnp.asarray(comm),
                                       lat))
    q2 = np.asarray(cm.latency_swarm(jnp.asarray(edge), jnp.asarray(comm),
                                     lat, quorum=2))
    assert (q2 <= full + 1e-9).all()
    assert q2.mean() < full.mean()


def test_swarm_round_issues_zero_probe_prefill_dispatches(system):
    """Probe-cache reuse acceptance: one answer_batch call must prefill the
    probe exactly ONCE (its own probe pass) — the swarm round reuses the
    probe's answer and warm cache handle instead of re-prefilling, even
    when every query is forced onto the swarm path."""
    import dataclasses as dc

    gw, probe, _, world = system
    gw = _fresh_sim(gw)
    old_cfg = gw.router_cfg
    # force every non-safety query into the Level-1 swarm round
    gw.router_cfg = dc.replace(old_cfg, tau_low=-1.0, tau_high=2.0)
    try:
        before = dict(probe.counters)
        log = gw.answer_batch(world.study_workload(4, 4, 0))
    finally:
        gw.router_cfg = old_cfg
    assert (log.decision == SWARM).any()
    assert probe.counters["prefill"] == before["prefill"] + 1
    assert probe.counters["prefill_continue"] == before["prefill_continue"]
    assert probe.counters["decode_only"] == before["decode_only"]


def test_distill_buffer_collects_cloud_queries(system):
    gw, _, _, world = system
    gw = _fresh_sim(gw)
    n0 = len(gw.distill_buffer.items)
    gw.answer_batch(world.safety_queries(6, borderline_frac=0.0))
    assert len(gw.distill_buffer.items) >= n0  # grew (or stayed if refused)


def test_privacy_log_consistency(system):
    gw, _, _, world = system
    gw = _fresh_sim(gw)
    log = gw.answer_batch(world.study_workload(6, 6, 4))
    pm = log.privacy()
    assert 0.0 <= float(pm.cer) <= 1.0
    assert 0.0 <= float(pm.ter) <= 1.0
    assert 0.0 <= float(pm.ser) <= 1.0
    np.testing.assert_allclose(log.cloud_usage(), float(pm.cer), atol=1e-6)


def test_moe_swarm_member_answers_study_query():
    """A MoE-config swarm member answers study queries end-to-end through
    SwarmExecutor's streaming serve() path — the serve() MoE refusal is
    gone, and the streamed answers are the member's own batched greedy
    generation (so consensus sees real MoE answers, not a fallback)."""
    import dataclasses

    import jax

    from repro import configs as C
    from repro.data.workload import FactWorld
    from repro.models import transformer as T
    from repro.serving.engine import InferenceEngine
    from repro.serving.swarm import SwarmExecutor, pad_prompts

    cfg = dataclasses.replace(C.get_smoke("deepseek-moe-16b"),
                              vocab_size=512)
    moe = InferenceEngine("moe-member", cfg,
                          T.init_params(cfg, jax.random.PRNGKey(1)))
    queries = FactWorld().easy_queries(3)
    prompts = pad_prompts([q["prompt"] for q in queries])
    out = SwarmExecutor([moe, moe], streaming=True,
                        serve_slots=2).collaborate(prompts, 4)
    direct = moe.generate(prompts, 4)
    assert out["answers"].shape == (3, 2, 4)
    for j in range(2):
        np.testing.assert_array_equal(out["answers"][:, j], direct["tokens"])
    np.testing.assert_array_equal(out["winner_tokens"], direct["tokens"])
    np.testing.assert_allclose(
        out["u"], np.broadcast_to(direct["u"][:, None], out["u"].shape),
        atol=1e-5)


def test_scheduler_continuous_batching():
    from repro.serving.scheduler import ContinuousBatcher, Request
    cb = ContinuousBatcher(2)
    for i in range(5):
        cb.submit(Request(rid=i, prompt=[1, 2], max_new=2))
    steps = 0
    while not cb.idle and steps < 50:
        cb.admit()
        active = cb.active_mask()
        cb.record_tokens(np.arange(2) + steps)
        steps += 1
    assert len(cb.finished) == 5
    assert steps <= 10


def test_peer_selection_deadline():
    from repro.serving.scheduler import select_peers
    pred = np.array([0.1, 5.0, 0.2, 0.3])
    mask = select_peers(pred, k=2, l_max=1.0)
    assert mask.tolist() == [True, False, True, False]


# ---------------------------------------------------------------------------
# Execution-level fault injection (serving/faults.py): chaos batches through
# the REAL gateway — retrying summon, circuit breaker, quorum salvage,
# deterministic re-runs, and healthy-path bitwise parity.
# ---------------------------------------------------------------------------

def _chaos(gw, plan):
    """Install a fault plan on gateway + swarm and rewind all fault state."""
    gw.faults = plan
    gw.swarm.faults = plan
    gw.reset_fault_state()
    return gw


def _clear_chaos(gw):
    gw.faults = None
    gw.swarm.faults = None
    gw.reset_fault_state()


def test_cloud_summon_retries_then_circuit_opens(system):
    """A dead cloud: the summon burns its full retry budget once, trips the
    breaker, and the batch degrades (no CLOUD decisions, every query still
    answered via O5).  The next batch skips the summon entirely (breaker
    open), the one after probes half-open and re-trips."""
    import dataclasses as dc

    from repro.serving.faults import FaultEvent, FaultPlan

    gw, _, _, world = system
    gw = _fresh_sim(gw, wan_outage_p=0.0)
    old_cfg = gw.router_cfg
    # route every non-safety query's phase A straight to CLOUD
    gw.router_cfg = dc.replace(old_cfg, tau_low=-2.0, tau_high=-1.0)
    try:
        _chaos(gw, FaultPlan([FaultEvent("cloud", "timeout", count=999)]))
        qs = world.study_workload(4, 4, 0)

        log1 = gw.answer_batch(qs)
        fc = log1.faults
        assert fc["cloud_attempts"] == gw.retry.max_attempts
        assert fc["cloud_retries"] == gw.retry.max_attempts - 1
        assert fc["cloud_exhausted"] == 1 and fc["breaker_opened"] == 1
        assert not np.isin(log1.decision, (CLOUD, CLOUD_SAFETY)).any()
        assert (fc["degraded_to_swarm"] + fc["degraded_to_local"]
                + fc["degraded_refused"]) >= 1
        assert log1.answered is not None
        # failed attempts carry realized latency: timeout * retries + backoff
        assert log1.latency.max() >= gw.retry.timeout_s \
            * (gw.retry.max_attempts - 1)

        log2 = gw.answer_batch(qs)          # breaker open: no summon at all
        assert log2.faults["cloud_attempts"] == 0
        assert log2.faults["breaker_open_skips"] == 1
        assert not np.isin(log2.decision, (CLOUD, CLOUD_SAFETY)).any()

        log3 = gw.answer_batch(qs)          # half-open probe, fails again
        assert log3.faults["cloud_attempts"] == gw.retry.max_attempts
        assert log3.faults["breaker_opened"] == 1
    finally:
        gw.router_cfg = old_cfg
        _clear_chaos(gw)


def test_flaky_cloud_retry_succeeds_within_budget(system):
    """One injected timeout < max_attempts: the retry salvages the summon —
    cloud answers arrive, the breaker stays closed, and the extra attempt's
    deadline + backoff shows up in the cloud queries' latency and cost."""
    import dataclasses as dc

    from repro.serving.faults import FaultEvent, FaultPlan

    gw, _, _, world = system
    gw = _fresh_sim(gw, wan_outage_p=0.0)
    old_cfg = gw.router_cfg
    gw.router_cfg = dc.replace(old_cfg, tau_low=-2.0, tau_high=-1.0)
    try:
        qs = world.study_workload(4, 4, 0)
        _clear_chaos(gw)
        base = gw.answer_batch(qs)
        _chaos(gw, FaultPlan([FaultEvent("cloud", "timeout", count=1)]))
        log = gw.answer_batch(qs)
        fc = log.faults
        assert fc["cloud_attempts"] == 2 and fc["cloud_retries"] == 1
        assert fc["cloud_exhausted"] == 0 and fc["breaker_opened"] == 0
        cloud_mask = np.isin(log.decision, (CLOUD, CLOUD_SAFETY))
        assert cloud_mask.any()
        np.testing.assert_array_equal(log.answers, base.answers)
        assert (log.latency[cloud_mask]
                >= base.latency[cloud_mask] + gw.retry.timeout_s).all()
        assert (log.cost[cloud_mask] > base.cost[cloud_mask]).all()
    finally:
        gw.router_cfg = old_cfg
        _clear_chaos(gw)


def test_member_crash_salvaged_by_survivors(system):
    """A member crashing mid-round is a casualty, not a failed batch: the
    consensus renormalizes over survivors, every query is answered, and
    repeated casualties mark the member unavailable in the health registry."""
    import dataclasses as dc

    from repro.serving.faults import FaultEvent, FaultPlan

    gw, _, _, world = system
    gw = _fresh_sim(gw, wan_outage_p=0.0)
    old_cfg = gw.router_cfg
    # force the Level-1 swarm round for every non-safety query
    gw.router_cfg = dc.replace(old_cfg, tau_low=-1.0, tau_high=2.0)
    try:
        _chaos(gw, FaultPlan([FaultEvent("member:1", "crash", count=999)]))
        qs = world.study_workload(4, 4, 0)
        log1 = gw.answer_batch(qs)
        assert (log1.decision == SWARM).any()
        assert log1.faults["member_casualties"] >= 1
        assert log1.availability() == 1.0   # salvage: everything answered
        gw.answer_batch(qs)                 # second consecutive casualty...
        assert not gw.health.available()[1]  # ...downs it (fail_threshold=2)
    finally:
        gw.router_cfg = old_cfg
        _clear_chaos(gw)


def test_chaos_workload_answers_all_and_is_deterministic(system):
    """Acceptance: a seeded plan combining a member crash, a flaky cloud
    (retried within budget), a straggler, and pool famine still answers
    every query — and two runs bracketed by reset_fault_state() agree
    bitwise on answers, decisions, latency, cost, and fault counters."""
    import dataclasses as dc

    from repro.serving.faults import FaultEvent, FaultPlan

    gw, _, _, world = system
    gw = _fresh_sim(gw, wan_outage_p=0.0)
    old_cfg = gw.router_cfg
    # force a swarm round every batch so the tick-pinned member events
    # actually meet a round (safety queries still summon the cloud)
    gw.router_cfg = dc.replace(old_cfg, tau_low=-1.0, tau_high=2.0)
    qs = world.study_workload(6, 6, 4)

    def plan():
        return FaultPlan([
            FaultEvent("member:0", "crash", tick=1, count=1),
            FaultEvent("member:2", "straggle", tick=2, count=1, delay_s=2.0),
            FaultEvent("cloud", "timeout", tick=1, count=1),
            FaultEvent("pool", "famine", tick=2, count=1),
        ], seed=11)

    def run():
        gw.reset_fault_state()
        return [gw.answer_batch(qs) for _ in range(3)]

    _chaos(gw, plan())
    try:
        runs_a = run()
        runs_b = run()
        for log_a, log_b in zip(runs_a, runs_b):
            assert log_a.availability() == 1.0
            np.testing.assert_array_equal(log_a.answers, log_b.answers)
            np.testing.assert_array_equal(log_a.decision, log_b.decision)
            np.testing.assert_array_equal(log_a.latency, log_b.latency)
            np.testing.assert_array_equal(log_a.cost, log_b.cost)
            assert log_a.faults == log_b.faults
        total = {}
        for log in runs_a:
            for k, v in log.faults.items():
                total[k] = total.get(k, 0) + v
        assert total["member_casualties"] >= 1
        assert total["cloud_retries"] >= 1 and total["cloud_exhausted"] == 0
    finally:
        gw.router_cfg = old_cfg
        _clear_chaos(gw)


def test_empty_faultplan_is_bitwise_noop(system):
    """Healthy-path parity: an installed-but-empty FaultPlan must leave
    answers, routing, latency and cost bitwise identical to faults=None."""
    from repro.serving.faults import FaultPlan

    gw, _, _, world = system
    gw = _fresh_sim(gw)
    qs = world.study_workload(4, 4, 2)
    try:
        _clear_chaos(gw)
        log0 = gw.answer_batch(qs)
        _chaos(gw, FaultPlan([]))
        log1 = gw.answer_batch(qs)
        np.testing.assert_array_equal(log0.answers, log1.answers)
        np.testing.assert_array_equal(log0.decision, log1.decision)
        np.testing.assert_array_equal(log0.latency, log1.latency)
        np.testing.assert_array_equal(log0.cost, log1.cost)
        assert log1.availability() == log0.availability() == 1.0
        # identical counters too (cloud_attempts counts the healthy summon)
        assert log1.faults == log0.faults
        assert all(log1.faults[k] == 0 for k in
                   ("cloud_retries", "cloud_failures", "cloud_exhausted",
                    "breaker_opened", "member_casualties", "famine_deferred",
                    "shed", "requeued", "reprefill_cold", "expired"))
    finally:
        _clear_chaos(gw)
