"""Gateway integration tests: Algorithm 1 end-to-end with quickly-trained
tiers, fault injection (O5 chain), budget caps, quorum straggler mitigation.

Kept fast: short training (the routing logic under test doesn't need
memorised facts; accuracy-level behaviour is covered by benchmarks/tables).
"""

import numpy as np
import pytest

from repro.core.cost_model import LatencyParams
from repro.core.router import (CLOUD, CLOUD_SAFETY, LOCAL, REFUSE, SWARM,
                               RouterConfig)
from repro.data.workload import FactWorld
from repro.serving.simulator import NetworkSimulator, SimConfig


@pytest.fixture(scope="module")
def system():
    from repro.launch.serve import build_gateway
    gw, probe, cloud, world = build_gateway(train_steps=40, calibrate=True)
    return gw, probe, cloud, world


def _fresh_sim(gw, **kw):
    gw.sim = NetworkSimulator(SimConfig(**kw), LatencyParams(),
                              n_members=len(gw.swarm.members))
    return gw


def test_decisions_are_valid_codes(system):
    gw, _, _, world = system
    log = gw.answer_batch(world.study_workload(6, 6, 4))
    assert set(np.unique(log.decision)) <= {LOCAL, SWARM, CLOUD,
                                            CLOUD_SAFETY, REFUSE}
    assert log.latency.min() > 0
    assert len(log.category) == 16


def test_safety_queries_escalate_or_refuse(system):
    gw, _, _, world = system
    qs = world.safety_queries(8, borderline_frac=0.0)
    log = gw.answer_batch(qs)
    assert np.isin(log.decision, (CLOUD_SAFETY, REFUSE)).mean() >= 0.75


def test_wan_outage_degrades_gracefully(system):
    """O5: cloud -> swarm -> local, never crash, no cloud decisions."""
    gw, _, _, world = system
    gw = _fresh_sim(gw, wan_outage_p=1.0, wan_recover_p=0.0)
    log = gw.answer_batch(world.study_workload(4, 4, 2))
    cloud_mask = np.isin(log.decision, (CLOUD, CLOUD_SAFETY))
    assert not cloud_mask.any()
    assert np.isin(log.decision, (LOCAL, SWARM, REFUSE)).all()
    _fresh_sim(gw)


def test_budget_cap_blocks_cloud(system):
    gw, _, _, world = system
    gw = _fresh_sim(gw)
    old_total = gw.budget.total
    import repro.core.budget as B
    gw.budget = B.init_budget(0.0)
    log = gw.answer_batch(world.study_workload(4, 4, 2))
    assert not np.isin(log.decision, (CLOUD, CLOUD_SAFETY)).any()
    gw.budget = B.init_budget(float(old_total))


def test_node_failure_swarm_still_answers(system):
    gw, _, _, world = system
    gw = _fresh_sim(gw, node_fail_p=1.0, node_recover_p=0.0)
    gw.sim.tick()
    assert not gw.sim.member_up.any()
    log = gw.answer_batch(world.study_workload(4, 4, 0))
    assert len(log.decision) == 8          # answers produced regardless
    _fresh_sim(gw)


def test_quorum_reduces_swarm_tail_latency(system):
    """Beyond-paper straggler mitigation: quorum-k <= full-swarm latency."""
    from repro.core import cost_model as cm
    lat = LatencyParams(agg_overhead=0.0)
    rng = np.random.RandomState(0)
    edge = rng.rand(200, 3) + 0.5
    comm = rng.rand(200, 3) * 0.2
    import jax.numpy as jnp
    full = np.asarray(cm.latency_swarm(jnp.asarray(edge), jnp.asarray(comm),
                                       lat))
    q2 = np.asarray(cm.latency_swarm(jnp.asarray(edge), jnp.asarray(comm),
                                     lat, quorum=2))
    assert (q2 <= full + 1e-9).all()
    assert q2.mean() < full.mean()


def test_swarm_round_issues_zero_probe_prefill_dispatches(system):
    """Probe-cache reuse acceptance: one answer_batch call must prefill the
    probe exactly ONCE (its own probe pass) — the swarm round reuses the
    probe's answer and warm cache handle instead of re-prefilling, even
    when every query is forced onto the swarm path."""
    import dataclasses as dc

    gw, probe, _, world = system
    gw = _fresh_sim(gw)
    old_cfg = gw.router_cfg
    # force every non-safety query into the Level-1 swarm round
    gw.router_cfg = dc.replace(old_cfg, tau_low=-1.0, tau_high=2.0)
    try:
        before = dict(probe.counters)
        log = gw.answer_batch(world.study_workload(4, 4, 0))
    finally:
        gw.router_cfg = old_cfg
    assert (log.decision == SWARM).any()
    assert probe.counters["prefill"] == before["prefill"] + 1
    assert probe.counters["prefill_continue"] == before["prefill_continue"]
    assert probe.counters["decode_only"] == before["decode_only"]


def test_distill_buffer_collects_cloud_queries(system):
    gw, _, _, world = system
    gw = _fresh_sim(gw)
    n0 = len(gw.distill_buffer.items)
    gw.answer_batch(world.safety_queries(6, borderline_frac=0.0))
    assert len(gw.distill_buffer.items) >= n0  # grew (or stayed if refused)


def test_privacy_log_consistency(system):
    gw, _, _, world = system
    gw = _fresh_sim(gw)
    log = gw.answer_batch(world.study_workload(6, 6, 4))
    pm = log.privacy()
    assert 0.0 <= float(pm.cer) <= 1.0
    assert 0.0 <= float(pm.ter) <= 1.0
    assert 0.0 <= float(pm.ser) <= 1.0
    np.testing.assert_allclose(log.cloud_usage(), float(pm.cer), atol=1e-6)


def test_moe_swarm_member_answers_study_query():
    """A MoE-config swarm member answers study queries end-to-end through
    SwarmExecutor's streaming serve() path — the serve() MoE refusal is
    gone, and the streamed answers are the member's own batched greedy
    generation (so consensus sees real MoE answers, not a fallback)."""
    import dataclasses

    import jax

    from repro import configs as C
    from repro.data.workload import FactWorld
    from repro.models import transformer as T
    from repro.serving.engine import InferenceEngine
    from repro.serving.swarm import SwarmExecutor, pad_prompts

    cfg = dataclasses.replace(C.get_smoke("deepseek-moe-16b"),
                              vocab_size=512)
    moe = InferenceEngine("moe-member", cfg,
                          T.init_params(cfg, jax.random.PRNGKey(1)))
    queries = FactWorld().easy_queries(3)
    prompts = pad_prompts([q["prompt"] for q in queries])
    out = SwarmExecutor([moe, moe], streaming=True,
                        serve_slots=2).collaborate(prompts, 4)
    direct = moe.generate(prompts, 4)
    assert out["answers"].shape == (3, 2, 4)
    for j in range(2):
        np.testing.assert_array_equal(out["answers"][:, j], direct["tokens"])
    np.testing.assert_array_equal(out["winner_tokens"], direct["tokens"])
    np.testing.assert_allclose(
        out["u"], np.broadcast_to(direct["u"][:, None], out["u"].shape),
        atol=1e-5)


def test_scheduler_continuous_batching():
    from repro.serving.scheduler import ContinuousBatcher, Request
    cb = ContinuousBatcher(2)
    for i in range(5):
        cb.submit(Request(rid=i, prompt=[1, 2], max_new=2))
    steps = 0
    while not cb.idle and steps < 50:
        cb.admit()
        active = cb.active_mask()
        cb.record_tokens(np.arange(2) + steps)
        steps += 1
    assert len(cb.finished) == 5
    assert steps <= 10


def test_peer_selection_deadline():
    from repro.serving.scheduler import select_peers
    pred = np.array([0.1, 5.0, 0.2, 0.3])
    mask = select_peers(pred, k=2, l_max=1.0)
    assert mask.tolist() == [True, False, True, False]
