"""Sharded-lowering tests on a small fake-device mesh.

These run in a SUBPROCESS because the XLA host-device-count flag must be set
before jax initialises (and must NOT leak into the other tests, which assume
1 device).  Mirrors what launch/dryrun.py does at 512 devices.
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro import configs as C
from repro.models import transformer as T
from repro.training import optimizer as opt, train as TR
from repro.distributed import sharding as sh

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
out = {}
for arch in %ARCHS%:
    cfg = C.get_smoke(arch)
    abs_p = T.abstract_params(cfg)
    step = TR.build_train_step(cfg, opt.AdamWConfig(), mesh, moe_groups=4)
    batch = {}
    B, S = 8, 32
    if cfg.family in ("encoder", "audio"):
        batch["frontend_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        st = S
    elif cfg.frontend == "vision_patches":
        F = cfg.frontend_tokens
        batch["frontend_embeds"] = jax.ShapeDtypeStruct((B, F, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = jax.ShapeDtypeStruct((B, S - F), jnp.int32)
        st = S - F
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        st = S
    batch["labels"] = jax.ShapeDtypeStruct((B, st), jnp.int32)
    batch["loss_mask"] = jax.ShapeDtypeStruct((B, st), jnp.float32)
    with mesh:
        compiled = step.lower(abs_p, opt.abstract_state(abs_p), batch).compile()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca    # jax<0.5 returns [dict]
    out[arch] = {"flops": ca.get("flops", 0.0)}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.parametrize("archs", [
    ["smollm-135m", "mamba2-780m"],
    ["recurrentgemma-2b", "deepseek-moe-16b"],
])
def test_multipod_lowering_smokes(archs):
    script = SCRIPT.replace("%ARCHS%", json.dumps(archs))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, proc.stdout
    res = json.loads(line[0][len("RESULT "):])
    for arch in archs:
        assert res[arch]["flops"] > 0


def test_spec_builder_divisibility():
    """Non-divisible dims fall back to replication, never crash.

    spec_for only consults mesh.shape, so a lightweight stand-in lets us
    test production-sized (16, 16) axes on a 1-device container.
    """
    from types import SimpleNamespace
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as sh
    mesh = SimpleNamespace(shape={"data": 16, "model": 16})
    # 9 heads / 3 embed: neither divides 16 -> fully replicated
    assert sh.spec_for((9, 3), ("heads", "embed"), mesh,
                       sh.PARAM_RULES) == P()
    # 32 heads / 64 embed: both shard
    assert sh.spec_for((32, 64), ("heads", "embed"), mesh,
                       sh.PARAM_RULES) == P("model", "data")
    # KV-cache priority: 8 kv heads can't take 'model', seq dim does
    spec = sh.spec_for((128, 4096, 8, 128),
                       ("act_batch", "act_kv_seq", "act_kv_heads", None),
                       mesh, sh.ACT_RULES)
    assert spec == P("data", "model")
    # ...and heads win over seq when they divide
    spec = sh.spec_for((128, 4096, 16, 128),
                       ("act_batch", "act_kv_seq", "act_kv_heads", None),
                       mesh, sh.ACT_RULES)
    assert spec == P("data", None, "model")


def test_dryrun_artifacts_if_present():
    """If the sweep has run, every runnable cell must be ok on both meshes."""
    from repro import configs as C
    d = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "dryrun")
    if not os.path.isdir(d) or len(os.listdir(d)) < 80:
        pytest.skip("dry-run sweep artifacts not present")
    bad = []
    for arch, shape, skip in C.cells(include_skipped=True):
        for mesh in ("single", "multi"):
            p = os.path.join(d, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(p):
                bad.append((arch, shape, mesh, "missing"))
                continue
            rec = json.load(open(p))
            if skip is None and not rec.get("ok"):
                bad.append((arch, shape, mesh, rec.get("error", "?")[:80]))
            if skip is not None and "skipped" not in rec:
                bad.append((arch, shape, mesh, "should be skipped"))
    assert not bad, bad
