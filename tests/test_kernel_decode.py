"""Kernel-first paged decode (ISSUE 6): engine-level parity + accounting.

The kernel-first serve path (``attn_decode_impl="kernel"``) must be
BITWISE-identical — tokens AND logits — to the gathered-view oracle
(``"gather"``) AND to the monolithic engine, for all three mixer families
and both MoE archs, cold / warm-continuation / decode-only, unsharded and
on the (1, 1) mesh; the real (4, 2) fake-device mesh runs tie-aware in a
subprocess like the other sharded suites.  On top of parity:

* the kernel-first decode executable provably never materialises the
  O(B * S) slot-linear attention KV view — HLO live-buffer accounting
  (the gathered-view executable DOES carry it, so the probe is sound);
* with ``compilation_cache_dir`` set, a second process constructing the
  same engine and running the same dispatch performs ZERO fresh XLA
  compiles — every executable comes off the persistent cache (entry
  thresholds are zeroed, so any fresh compile would write a new file).
"""

import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import configs as C
from repro.core.uncertainty import UncertaintyConfig
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import Request
from repro.serving.swarm import pad_prompts

ARCHS = {
    "attn": "smollm-135m",
    "rglru": "recurrentgemma-2b",
    "ssd": "mamba2-780m",
    "moe_shared_routed": "deepseek-moe-16b",
    "moe_interleaved": "llama4-scout-17b-a16e",
}

BLOCK = 16
PROMPTS = [[3, 20, 195, 2], [3, 21, 196, 199, 2], [7, 9, 2]]
SPANS = [[11, 12, 2], [13, 2], [14, 15, 16, 2]]


def _triple(arch: str, **kw):
    cfg = dataclasses.replace(C.get_smoke(arch), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ucfg = UncertaintyConfig(mode="distribution")
    mono = InferenceEngine("mono", cfg, params, ucfg)
    gather = InferenceEngine("gather", cfg, params, ucfg, paged=True,
                             block_len=BLOCK, attn_decode_impl="gather", **kw)
    kernel = InferenceEngine("kernel", cfg, params, ucfg, paged=True,
                             block_len=BLOCK, attn_decode_impl="kernel", **kw)
    return mono, gather, kernel


@pytest.fixture(scope="module", params=sorted(ARCHS))
def triple(request):
    return _triple(ARCHS[request.param])


def _assert_same(r0, r1, logits=True):
    np.testing.assert_array_equal(r0["tokens"], r1["tokens"])
    if logits:
        np.testing.assert_array_equal(np.asarray(r0["logits"]),
                                      np.asarray(r1["logits"]))


class TestKernelFirstParity:
    def test_generate_bitwise_triple(self, triple):
        """Cold fused generate: kernel == gather == mono, tokens AND
        logits AND uncertainty, every arch."""
        mono, gather, kernel = triple
        prompts = pad_prompts(PROMPTS)
        r0 = mono.generate(prompts, 6)
        rg = gather.generate(prompts, 6)
        rk = kernel.generate(prompts, 6)
        _assert_same(r0, rg)
        _assert_same(r0, rk)
        np.testing.assert_array_equal(r0["u"], rk["u"])

    def test_warm_continuation_and_extension_bitwise(self, triple):
        """absorb -> continue -> decode-only extend stays bitwise across
        all three decode layouts."""
        mono, gather, kernel = triple
        prompts, span = pad_prompts(PROMPTS), pad_prompts(SPANS)
        outs = []
        for eng in (mono, gather, kernel):
            w = eng.generate(span, 6, state=eng.absorb(prompts),
                             return_state=True)
            e = eng.generate(None, 4, state=w["state"])
            outs.append((w, e))
        for w, e in outs[1:]:
            _assert_same(outs[0][0], w)
            _assert_same(outs[0][1], e)

    def test_serve_bitwise(self, triple):
        """Streaming serve through the kernel-first slots == monolithic
        generate, token for token."""
        mono, _, kernel = triple
        prompts = pad_prompts(PROMPTS)
        res = mono.generate(prompts, 6)
        fin = kernel.serve([Request(rid=i, prompt=prompts[i].tolist(),
                                    max_new=6) for i in range(len(PROMPTS))],
                           n_slots=2, decode_chunk=4)
        assert len(fin) == len(PROMPTS)
        for r in fin:
            np.testing.assert_array_equal(r["tokens"],
                                          res["tokens"][r["rid"]])

    def test_mesh11_bitwise(self, triple):
        """Kernel-first + the degenerate (1,1) serving mesh == monolithic
        unsharded, bit for bit."""
        from repro.launch.mesh import serving_mesh
        mono, _, _ = triple
        sh = InferenceEngine("kernel-mesh", mono.cfg, mono.params, mono.ucfg,
                             paged=True, block_len=BLOCK,
                             attn_decode_impl="kernel", mesh=serving_mesh())
        prompts = pad_prompts(PROMPTS)
        r0 = mono.generate(prompts, 6)
        r1 = sh.generate(prompts, 6)
        _assert_same(r0, r1)


# ---------------------------------------------------------------------------
# HLO live-buffer accounting: the gathered-view decode executable carries
# the O(B * S) slot-linear attention KV view; the kernel-first one must not.
# (Probes live in repro.serving.hlo_probe, shared with the microbench/CI.)
# ---------------------------------------------------------------------------


class TestNoSlotLinearKV:
    @pytest.mark.parametrize("arch", ["attn", "rglru"])
    def test_kernel_first_drops_gathered_view(self, arch):
        from repro.serving.hlo_probe import assert_no_slot_linear_kv
        _, gather, kernel = _triple(ARCHS[arch])
        acct = assert_no_slot_linear_kv(gather, kernel, pad_prompts(PROMPTS))
        assert acct["view_types"] and not acct["in_kernel_hlo"]


# ---------------------------------------------------------------------------
# Persistent compilation cache: second process == zero fresh compiles
# ---------------------------------------------------------------------------

CACHE_SCRIPT = r"""
import sys
import numpy as np
from repro import configs as C
import dataclasses, jax
from repro.core.uncertainty import UncertaintyConfig
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.swarm import pad_prompts

cfg = dataclasses.replace(C.get_smoke("smollm-135m"), vocab_size=512)
params = T.init_params(cfg, jax.random.PRNGKey(0))
eng = InferenceEngine("e", cfg, params, UncertaintyConfig(mode="distribution"),
                      paged=True, block_len=16,
                      compilation_cache_dir=sys.argv[1])
res = eng.generate(pad_prompts([[3, 20, 195, 2], [7, 9, 2], [5, 6, 2]]), 6,
                   return_state=True)
res2 = eng.generate(None, 4, state=res["state"])
np.save(sys.argv[2], res["tokens"])
print("RESULT ok")
"""


def test_second_process_compiles_nothing_fresh(tmp_path):
    """Entry-size/compile-time thresholds are zeroed, so EVERY fresh XLA
    compile persists a new cache file: an unchanged file set on the second
    run proves every executable (prefill, decode scan, uncertainty) came
    off the persistent cache — the serve() cold start is jit-free."""
    cache_dir = tmp_path / "xla-cache"
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))

    def run(tag):
        out = tmp_path / f"toks-{tag}.npy"
        proc = subprocess.run(
            [sys.executable, "-c", CACHE_SCRIPT, str(cache_dir), str(out)],
            env=env, capture_output=True, text=True, timeout=600)
        assert proc.returncode == 0, proc.stderr[-3000:]
        assert "RESULT ok" in proc.stdout
        return np.load(out), sorted(os.listdir(cache_dir))

    toks1, files1 = run(1)
    assert files1                       # run 1 populated the cache
    toks2, files2 = run(2)
    assert files2 == files1, (len(files1), len(files2))
    np.testing.assert_array_equal(toks1, toks2)


# ---------------------------------------------------------------------------
# Multi-device sharded parity (subprocess — fake-device flag needs a fresh
# process, see test_prefill_parity.py)
# ---------------------------------------------------------------------------

KERNEL_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, numpy as np
from repro import configs as C
from repro.core.uncertainty import UncertaintyConfig
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.swarm import pad_prompts
from repro.launch.mesh import serving_mesh

PROMPTS = [[3, 20, 195, 2], [3, 21, 196, 199, 2], [7, 9, 2], [5, 6, 7, 2]]
mesh = serving_mesh(model_parallel=2)
assert dict(mesh.shape) == {"data": 4, "model": 2}, mesh.shape
for arch in ("smollm-135m", "mamba2-780m"):
    cfg = dataclasses.replace(C.get_smoke(arch), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ucfg = UncertaintyConfig(mode="distribution")
    base = InferenceEngine(arch, cfg, params, ucfg)
    engs = {impl: InferenceEngine(arch, cfg, params, ucfg, paged=True,
                                  block_len=16, pool_blocks=256, mesh=mesh,
                                  attn_decode_impl=impl)
            for impl in ("gather", "kernel")}
    prompts = pad_prompts(PROMPTS)
    r0 = base.generate(prompts, 6)
    rg = engs["gather"].generate(prompts, 6)
    rk = engs["kernel"].generate(prompts, 6)
    # kernel-first vs gathered-view on the SAME mesh: identical partitioned
    # reductions over elementwise-equal chunk streams -> exact
    np.testing.assert_array_equal(rg["tokens"], rk["tokens"])
    np.testing.assert_array_equal(np.asarray(rg["logits"]),
                                  np.asarray(rk["logits"]))
    # vs the single-device engine: tie-aware (sharded reductions carry
    # ~1 bf16 ulp, same noise class as the monolithic sharded path)
    l0, l1 = np.asarray(r0["logits"]), np.asarray(rk["logits"])
    for b in range(r0["tokens"].shape[0]):
        mism = np.where(r0["tokens"][b] != rk["tokens"][b])[0]
        n = mism[0] if len(mism) else r0["tokens"].shape[1]
        np.testing.assert_array_equal(r0["tokens"][b, :n],
                                      rk["tokens"][b, :n])
        np.testing.assert_allclose(l0[b, :n], l1[b, :n], atol=0.01, rtol=0)
        if len(mism):
            top2 = np.sort(l0[b, mism[0]])[-2:]
            assert top2[1] - top2[0] <= 0.02, (arch, b, mism[0], top2)
    print(arch, "ok", flush=True)
print("RESULT ok")
"""


def test_kernel_sharded_matches_gather_and_single_device():
    """Kernel-first on a real (data=4, model=2) fake-device mesh: exact
    parity with the gathered-view engine on the same mesh, tie-aware
    greedy parity with the single-device engine."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", KERNEL_SHARDED_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RESULT ok" in proc.stdout, proc.stdout
