"""Property-based tests (hypothesis) on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; don't abort collection
from hypothesis import given, settings, strategies as st

from repro.core import budget as B
from repro.core import consensus as CO
from repro.training import compression as CP

SET = settings(max_examples=25, deadline=None)


class TestConsensusInvariants:
    @SET
    @given(st.integers(2, 6), st.integers(1, 5),
           st.lists(st.floats(0, 1), min_size=6, max_size=6),
           st.integers(0, 10 ** 6))
    def test_scores_bounded_and_winner_max(self, n, T, us, seed):
        rng = np.random.RandomState(seed)
        answers = jnp.asarray(rng.randint(0, 3, size=(n, T)))
        u = jnp.asarray(np.array(us[:n], np.float32))
        res = CO.weighted_consensus(answers, u)
        assert 0.0 <= float(res.best_score) <= 1.0 + 1e-6
        assert float(res.best_score) >= float(res.scores.max()) - 1e-6
        # weights respect the clip floor
        assert (np.asarray(res.weights) >= 0.05 - 1e-7).all()
        # every member's cluster score is in (0, 1]
        assert (np.asarray(res.scores) > 0).all()

    @SET
    @given(st.integers(0, 10 ** 6))
    def test_identical_answers_score_one(self, seed):
        rng = np.random.RandomState(seed)
        row = rng.randint(0, 5, size=(4,))
        answers = jnp.asarray(np.tile(row, (3, 1)))
        u = jnp.asarray(rng.rand(3).astype(np.float32))
        res = CO.weighted_consensus(answers, u)
        np.testing.assert_allclose(float(res.best_score), 1.0, atol=1e-6)

    @SET
    @given(st.integers(0, 10 ** 6))
    def test_permutation_invariance_of_best_score(self, seed):
        rng = np.random.RandomState(seed)
        answers = rng.randint(0, 3, size=(4, 3))
        u = rng.rand(4).astype(np.float32)
        perm = rng.permutation(4)
        r1 = CO.weighted_consensus(jnp.asarray(answers), jnp.asarray(u))
        r2 = CO.weighted_consensus(jnp.asarray(answers[perm]),
                                   jnp.asarray(u[perm]))
        np.testing.assert_allclose(float(r1.best_score),
                                   float(r2.best_score), atol=1e-6)


class TestBudgetInvariants:
    @SET
    @given(st.lists(st.floats(0, 0.1), min_size=1, max_size=16),
           st.floats(0, 0.5))
    def test_never_exceeds_total(self, costs, total):
        costs_a = jnp.asarray(np.array(costs, np.float32))
        wants = jnp.ones((len(costs),), bool)
        adm, st_ = B.charge_batch(B.init_budget(total), costs_a, wants)
        assert float(st_.used) <= total + 1e-5
        # admitted set is a prefix-feasible greedy: each admitted query fit
        # at its turn
        used = 0.0
        for c, a in zip(costs, np.asarray(adm)):
            if a:
                assert used + c <= total + 1e-6
                used += c


class TestCompressionInvariants:
    @SET
    @given(st.integers(0, 10 ** 6), st.integers(4, 256))
    def test_quantise_roundtrip_error_bound(self, seed, n):
        rng = np.random.RandomState(seed)
        x = jnp.asarray(rng.randn(n).astype(np.float32))
        q, scale = CP.quantise_int8(x)
        err = np.abs(np.asarray(CP.dequantise_int8(q, scale) - x))
        assert err.max() <= float(scale) / 2 + 1e-6

    @SET
    @given(st.integers(0, 10 ** 6))
    def test_error_feedback_is_lossless_in_aggregate(self, seed):
        """Sum of (transmitted + residual) equals the true gradient."""
        rng = np.random.RandomState(seed)
        g = jnp.asarray(rng.randn(64).astype(np.float32))
        err = jnp.zeros_like(g)
        q, scale, new_err = CP.compress_with_feedback(g, err)
        sent = CP.dequantise_int8(q, scale)
        np.testing.assert_allclose(np.asarray(sent + new_err),
                                   np.asarray(g), rtol=1e-5, atol=1e-5)


class TestShardingInvariants:
    @SET
    @given(st.integers(1, 512), st.integers(1, 64), st.integers(0, 3))
    def test_spec_divisibility(self, d0, d1, pick):
        import os
        import jax
        from jax.sharding import Mesh
        from repro.distributed import sharding as sh
        devs = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(devs, ("data", "model"))
        names = [None, "embed", "heads", "act_batch"]
        spec = sh.spec_for((d0, d1), (names[pick], "ffn"), mesh,
                           dict(sh.PARAM_RULES, **sh.ACT_RULES))
        # every assigned axis must divide its dim (sizes are 1 here, so the
        # property reduces to: no crash + valid PartitionSpec)
        assert spec is not None


class TestStagePlanInvariant:
    @SET
    @given(st.integers(1, 64), st.integers(0, 2))
    def test_stage_plan_reconstructs_layer_plan(self, layers, kind):
        from repro.models.common import ModelConfig
        pattern = [("attn",), ("rglru", "rglru", "attn_local"),
                   ("ssd",)][kind]
        cfg = ModelConfig(num_layers=layers, mixer_pattern=pattern,
                          window=8 if kind == 1 else None)
        flat = []
        for stage in cfg.stage_plan():
            for _ in range(stage.repeat):
                flat.extend(stage.blocks)
        assert tuple(flat) == cfg.layer_plan()
