"""Serving-path MoE routing tests: capacity-aware masked dispatch.

MoE configs are first-class citizens of the fused jitted-prefill +
scanned-decode runtime (no ``generate`` stepwise fallback, no ``serve()``
refusal).  The serving dispatch routes one group per prompt position with
drop-free capacity, so the fused path makes exactly the routing decisions
the sequential oracle makes:

* fused ``generate`` == ``generate_stepwise`` greedy tokens, for
  DeepSeek-style (top-6 + 2 shared) and Llama-4-Scout-style (top-1 +
  shared) configs, on the no-mesh path and the degenerate (1, 1) serving
  mesh (the real (4, 2) mesh runs in test_prefill_parity's subprocess);
* bucket padding is bitwise-neutral end-to-end, and at the block level a
  padding token can never consume a real expert's capacity slot — checked
  with a capacity-bounded config where any stolen slot would displace a
  real token;
* streaming ``serve()`` reproduces batched ``generate`` (mixed-request
  slot batches and garbage in empty slots cannot perturb routing).

Param seeds are pinned per arch to keep greedy argmaxes away from exact
bf16 logit ties: fused and stepwise absorption differ by ~1 ulp of
activation noise (the same tolerance the dense parity tests document), so
a random-init model whose top-2 logits collide bitwise would flip on
noise, not on a routing difference.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.core.uncertainty import UncertaintyConfig
from repro.models import moe as M
from repro.models import transformer as T
from repro.models.common import init_tree
from repro.serving import engine as E
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import Request
from repro.serving.swarm import pad_prompts

MOE_ARCHS = {"deepseek-moe-16b": 1, "llama4-scout-17b-a16e": 0}

PROMPTS = [[3, 20, 195, 2], [3, 21, 196, 199, 2], [7, 9, 2]]
RAGGED = PROMPTS + [[5] * 35]       # a length no attention-block bucket divides


def _engine(arch: str, mesh=None) -> InferenceEngine:
    cfg = dataclasses.replace(C.get_smoke(arch), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(MOE_ARCHS[arch]))
    return InferenceEngine(arch, cfg, params,
                           UncertaintyConfig(mode="distribution"), mesh=mesh)


@pytest.fixture(scope="module", params=sorted(MOE_ARCHS))
def engine(request):
    return _engine(request.param)


class TestFusedMoEParity:
    def test_generate_takes_fused_path(self, engine, monkeypatch):
        """Regression guard: MoE generate must never silently fall back to
        the stepwise loop again."""
        monkeypatch.setattr(
            engine, "generate_stepwise",
            lambda *a, **k: pytest.fail("MoE generate fell back to stepwise"))
        res = engine.generate(pad_prompts(PROMPTS), 4)
        assert res["tokens"].shape == (len(PROMPTS), 4)

    def test_tokens_and_u_match_stepwise(self, engine):
        prompts = pad_prompts(RAGGED)
        new = engine.generate(prompts, 6)
        old = engine.generate_stepwise(prompts, 6)
        np.testing.assert_array_equal(new["tokens"], old["tokens"])
        np.testing.assert_allclose(new["u"], old["u"], atol=1e-4)

    def test_bucket_padding_is_bitwise_neutral(self, engine):
        """Extra bucket columns (negative positions) must not change any
        generated logit — masked routing keeps them out of every capacity
        count, so padded and unpadded prompts dispatch identically."""
        prompts = pad_prompts(PROMPTS)      # S=5 -> bucket 8 inside generate
        B, S = prompts.shape
        res = engine.generate(prompts, 6)
        toks, lgs = E._generate_fused(
            engine.params, engine.cfg, jnp.asarray(prompts), jnp.int32(S),
            jax.random.PRNGKey(0), engine.ucfg, 6,
            engine._cache_len(E.bucket_len(S), 6), True)[:2]
        np.testing.assert_array_equal(res["tokens"], np.asarray(toks))
        np.testing.assert_array_equal(np.asarray(res["logits"]),
                                      np.asarray(lgs))

    def test_serve_matches_generate(self, engine):
        prompts = pad_prompts(RAGGED)
        res = engine.generate(prompts, 6)
        fin = engine.serve([Request(rid=i, prompt=prompts[i].tolist(),
                                    max_new=6) for i in range(len(RAGGED))],
                           n_slots=2, decode_chunk=4)
        assert len(fin) == len(RAGGED)
        for r in fin:
            np.testing.assert_array_equal(r["tokens"], res["tokens"][r["rid"]])
            np.testing.assert_allclose(r["u"], res["u"][r["rid"]], atol=1e-5)

    def test_degenerate_mesh_is_bitwise_identical(self):
        """The sharded MoE engine on the (1, 1) serving mesh must be
        bit-for-bit the unsharded engine — generate (tokens AND logits)
        and the streaming serve path."""
        from repro.launch.mesh import serving_mesh
        for arch in MOE_ARCHS:
            base = _engine(arch)
            shard = InferenceEngine(arch, base.cfg, base.params, base.ucfg,
                                    mesh=serving_mesh())
            prompts = pad_prompts(PROMPTS)
            r0 = base.generate(prompts, 6)
            r1 = shard.generate(prompts, 6)
            np.testing.assert_array_equal(r0["tokens"], r1["tokens"])
            np.testing.assert_array_equal(np.asarray(r0["logits"]),
                                          np.asarray(r1["logits"]))
            fin = shard.serve([Request(rid=i, prompt=prompts[i].tolist(),
                                       max_new=6)
                               for i in range(len(PROMPTS))], n_slots=2)
            for r in fin:
                np.testing.assert_array_equal(r["tokens"],
                                              r0["tokens"][r["rid"]])


# ---------------------------------------------------------------------------
# Block-level masked-dispatch semantics
# ---------------------------------------------------------------------------

def _moe_layer(cfg, key=0):
    return init_tree(M.moe_defs(cfg), jax.random.PRNGKey(key), cfg.dtype)


class TestMaskedDispatch:
    def test_padding_never_consumes_capacity_slots(self):
        """Bitwise routing invariance under a BINDING capacity: with the
        serve capacity bounded to 1 slot/expert, a padding token that
        slipped into a real expert's segment would displace a real token
        (different dispatch -> different bits).  Padding embeddings are
        scaled x10 so an unmasked router would definitely route them."""
        cfg = dataclasses.replace(C.get_smoke("deepseek-moe-16b"),
                                  moe_serve_capacity_factor=0.1)
        B, S, P = 4, 8, 5
        assert M.moe_serve_capacity(cfg, B) == 1     # binding
        p = _moe_layer(cfg)
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        out, _ = M.moe_prefill_block(p, x, cfg, pos)

        pad = 10.0 * jax.random.normal(jax.random.PRNGKey(4),
                                       (B, P, cfg.d_model), jnp.bfloat16)
        xp = jnp.concatenate([pad, x], axis=1)
        pos_p = jnp.broadcast_to(
            jnp.arange(S + P, dtype=jnp.int32)[None] - P, (B, S + P))
        out_p, aux = M.moe_prefill_block(p, xp, cfg, pos_p)
        np.testing.assert_array_equal(np.asarray(out_p[:, P:], jnp.float32),
                                      np.asarray(out, jnp.float32))
        assert np.isfinite(np.asarray(out_p, jnp.float32)).all()
        assert np.isfinite(float(aux))

    def test_prefill_dispatch_matches_decode_per_position(self):
        """The per-position prefill dispatch IS the decode dispatch run at
        every position: bitwise-identical block outputs — the property the
        fused/stepwise greedy parity rests on."""
        cfg = C.get_smoke("deepseek-moe-16b")
        p = _moe_layer(cfg)
        B, S = 4, 8
        x = jax.random.normal(jax.random.PRNGKey(7), (B, S, cfg.d_model),
                              jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        full, _ = M.moe_prefill_block(p, x, cfg, pos)
        steps = [M.moe_decode_block(p, x[:, s:s + 1], cfg)[0]
                 for s in range(S)]
        np.testing.assert_array_equal(
            np.asarray(full, jnp.float32),
            np.asarray(jnp.concatenate(steps, axis=1), jnp.float32))

    def test_serve_capacity_knob(self):
        cfg = C.get_smoke("deepseek-moe-16b")
        assert cfg.moe_serve_capacity_factor is None
        assert M.moe_serve_capacity(cfg, 16) == 16       # drop-free default
        bounded = dataclasses.replace(cfg, moe_serve_capacity_factor=1.25)
        assert 1 <= M.moe_serve_capacity(bounded, 64) <= 64
        assert M.moe_serve_capacity(bounded, 64) == 24   # round8(64*2/8*1.25)
        tiny = dataclasses.replace(cfg, moe_serve_capacity_factor=0.01)
        assert M.moe_serve_capacity(tiny, 4) == 1        # floor at 1

    def test_gather_decode_impl_close_to_dispatch(self):
        """The opt-in top-k weight-gather decode (k/E of the expert FLOPs)
        computes the same routed combination to activation-noise level."""
        cfg = C.get_smoke("deepseek-moe-16b")
        p = _moe_layer(cfg)
        x = jax.random.normal(jax.random.PRNGKey(5), (4, 1, cfg.d_model),
                              jnp.bfloat16)
        ref, _ = M.moe_decode_block(p, x, cfg)
        gat, _ = M.moe_decode_block(
            p, x, dataclasses.replace(cfg, moe_decode_impl="gather"))
        np.testing.assert_allclose(np.asarray(gat, jnp.float32),
                                   np.asarray(ref, jnp.float32),
                                   atol=2e-2, rtol=2e-2)

    def test_gather_decode_serves_end_to_end(self):
        cfg = dataclasses.replace(C.get_smoke("deepseek-moe-16b"),
                                  vocab_size=512, moe_decode_impl="gather")
        eng = InferenceEngine("moe-gather", cfg,
                              T.init_params(cfg, jax.random.PRNGKey(1)))
        res = eng.generate(pad_prompts(PROMPTS), 4)
        assert res["tokens"].shape == (len(PROMPTS), 4)
        assert np.isfinite(res["u"]).all()
