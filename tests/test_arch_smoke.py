"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (assignment requirement)."""


import jax
import jax.numpy as jnp
import pytest

from repro import configs as C
from repro.models import transformer as T
from repro.training import optimizer as opt
from repro.training import train as TR

ARCHS = list(C.ARCH_IDS)


def _batch(cfg, B=2, S=32, key=jax.random.PRNGKey(0)):
    batch = {}
    if cfg.family in ("encoder", "audio"):
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, S, cfg.d_model), jnp.bfloat16)
        s_text = S
    elif cfg.frontend == "vision_patches":
        F = cfg.frontend_tokens
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, F, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = jax.random.randint(key, (B, S - F), 0,
                                             cfg.vocab_size)
        s_text = S - F
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        s_text = S
    batch["labels"] = jax.random.randint(key, (B, s_text), 0, cfg.vocab_size)
    batch["loss_mask"] = jnp.ones((B, s_text), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = C.get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = T.forward(params, cfg, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nan(arch):
    cfg = C.get_smoke(arch)
    step = TR.build_train_step(cfg, opt.AdamWConfig(lr=1e-3), None)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    state = opt.init(params)
    params, state, m = step(params, state, _batch(cfg))
    assert not bool(jnp.isnan(m["loss"]))
    assert float(m["loss"]) > 0
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-780m",
                                  "recurrentgemma-2b", "deepseek-moe-16b",
                                  "llama4-scout-17b-a16e"])
def test_decode_step_no_nan(arch):
    cfg = C.get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = jax.tree.map(jnp.asarray, T.init_cache(cfg, 2, 16))
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache = T.decode_step(params, cfg, tok, cache,
                                  jnp.zeros((2,), jnp.int32))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-780m",
                                  "recurrentgemma-2b"])
def test_decode_matches_forward(arch):
    cfg = C.get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    full, _ = T.forward(params, cfg, {"tokens": toks})
    cache = jax.tree.map(jnp.asarray, T.init_cache(cfg, B, 32))
    outs = []
    for t in range(S):
        lg, cache = T.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                  jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1).astype(jnp.float32)
    err = float(jnp.abs(dec - full.astype(jnp.float32)).max())
    assert err < 5e-2, err


def test_full_config_params_match_scale():
    """Full (non-smoke) configs hit their nominal parameter scales."""
    expect = {
        "smollm-135m": (0.10e9, 0.18e9),
        "llama3-8b": (7e9, 9e9),
        "qwen1.5-110b": (95e9, 125e9),
        "command-r-plus-104b": (90e9, 115e9),
        "mamba2-780m": (0.6e9, 0.95e9),
        "recurrentgemma-2b": (2e9, 3.3e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
        "llama4-scout-17b-a16e": (95e9, 120e9),  # total (active 17B)
    }
    for arch, (lo, hi) in expect.items():
        n = C.get_config(arch).num_params()
        assert lo <= n <= hi, (arch, n)


def test_llama4_active_params():
    cfg = C.get_config("llama4-scout-17b-a16e")
    a = cfg.active_params()
    assert 15e9 <= a <= 25e9, a


def test_cells_enumeration():
    all_cells = C.cells(include_skipped=True)
    assert len(all_cells) == 40
    runnable = [c for c in all_cells if c[2] is None]
    skipped = [c for c in all_cells if c[2] is not None]
    assert len(runnable) == 31 and len(skipped) == 9
