"""Paged block-pool cache manager tests (ISSUE 5).

The paged engine (``InferenceEngine(paged=True)``) must be BITWISE-identical
to the monolithic engine — tokens AND logits — for all three mixer families
and both MoE archs, cold and warm, unsharded and on the (1, 1) mesh (the
real (4, 2) fake-device mesh runs in a subprocess, tie-aware like the other
sharded tests).  On top of parity:

* session growth appends blocks — ``counters["grow_copy"]`` stays 0 and the
  pool allocates incrementally (no whole-cache copy);
* fanning one absorbed prefix out to N slots issues exactly ONE prefill
  dispatch, and a shared block is never written through (COW checksum);
* serve() slots draw blocks from the pool, retire them back, and hand
  sessions off by table adoption; idle sessions are TTL-evicted and their
  handles raise on reuse, with the pool high-water mark bounded under
  churn;
* the ContinuousBatcher admits earliest-deadline-then-priority;
* the Pallas block-table decode kernel matches the gathered-view oracle.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.core.uncertainty import UncertaintyConfig
from repro.models import transformer as T
from repro.serving.cache_manager import EvictedSessionError
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import ContinuousBatcher, Request
from repro.serving.swarm import pad_prompts

ARCHS = {
    "attn": "smollm-135m",
    "rglru": "recurrentgemma-2b",
    "ssd": "mamba2-780m",
    "moe_shared_routed": "deepseek-moe-16b",
    "moe_interleaved": "llama4-scout-17b-a16e",
}

BLOCK = 16          # divides the recurrentgemma smoke window (32) and all
                    # cache bucket lengths (multiples of 64 / kv block 32)

PROMPTS = [[3, 20, 195, 2], [3, 21, 196, 199, 2], [7, 9, 2]]
SPANS = [[11, 12, 2], [13, 2], [14, 15, 16, 2]]


def _pair(arch: str, **kw):
    cfg = dataclasses.replace(C.get_smoke(arch), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ucfg = UncertaintyConfig(mode="distribution")
    mono = InferenceEngine("mono", cfg, params, ucfg)
    paged = InferenceEngine("paged", cfg, params, ucfg, paged=True,
                            block_len=BLOCK, **kw)
    return mono, paged


@pytest.fixture(scope="module", params=sorted(ARCHS))
def engines(request):
    return _pair(ARCHS[request.param])


class TestPagedParity:
    def test_generate_bitwise(self, engines):
        """Cold fused generate: tokens AND logits bitwise, every arch."""
        mono, paged = engines
        prompts = pad_prompts(PROMPTS)
        r0 = mono.generate(prompts, 6)
        r1 = paged.generate(prompts, 6)
        np.testing.assert_array_equal(r0["tokens"], r1["tokens"])
        np.testing.assert_array_equal(np.asarray(r0["logits"]),
                                      np.asarray(r1["logits"]))
        np.testing.assert_array_equal(r0["u"], r1["u"])

    def test_warm_continuation_and_extension_bitwise(self, engines):
        """absorb -> continue -> decode-only extend: the whole session API
        stays bitwise across cache representations."""
        mono, paged = engines
        prompts, span = pad_prompts(PROMPTS), pad_prompts(SPANS)
        w0 = mono.generate(span, 6, state=mono.absorb(prompts),
                           return_state=True)
        w1 = paged.generate(span, 6, state=paged.absorb(prompts),
                            return_state=True)
        np.testing.assert_array_equal(w0["tokens"], w1["tokens"])
        np.testing.assert_array_equal(np.asarray(w0["logits"]),
                                      np.asarray(w1["logits"]))
        e0 = mono.generate(None, 4, state=w0["state"])
        e1 = paged.generate(None, 4, state=w1["state"])
        np.testing.assert_array_equal(e0["tokens"], e1["tokens"])

    def test_serve_bitwise(self, engines):
        """Streaming serve through pool-backed slots == generate."""
        mono, paged = engines
        prompts = pad_prompts(PROMPTS)
        res = mono.generate(prompts, 6)
        fin = paged.serve([Request(rid=i, prompt=prompts[i].tolist(),
                                   max_new=6) for i in range(len(PROMPTS))],
                          n_slots=2, decode_chunk=4)
        assert len(fin) == len(PROMPTS)
        for r in fin:
            np.testing.assert_array_equal(r["tokens"],
                                          res["tokens"][r["rid"]])
            np.testing.assert_allclose(r["u"], res["u"][r["rid"]], atol=1e-5)

    def test_mesh11_bitwise(self, engines):
        """Paged + the degenerate (1,1) serving mesh == monolithic
        unsharded, bit for bit (generate and serve)."""
        from repro.launch.mesh import serving_mesh
        mono, paged = engines
        sh = InferenceEngine("paged-mesh", mono.cfg, mono.params, mono.ucfg,
                             paged=True, block_len=BLOCK,
                             mesh=serving_mesh())
        prompts = pad_prompts(PROMPTS)
        r0 = mono.generate(prompts, 6)
        r1 = sh.generate(prompts, 6)
        np.testing.assert_array_equal(r0["tokens"], r1["tokens"])
        np.testing.assert_array_equal(np.asarray(r0["logits"]),
                                      np.asarray(r1["logits"]))
        fin = sh.serve([Request(rid=i, prompt=prompts[i].tolist(),
                                max_new=6) for i in range(len(PROMPTS))],
                       n_slots=2)
        for r in fin:
            np.testing.assert_array_equal(r["tokens"],
                                          r0["tokens"][r["rid"]])


class TestGrowthWithoutCopy:
    def test_multiturn_growth_appends_blocks(self):
        """A session growing past its cache appends reset blocks: bitwise
        vs the monolithic grow-and-copy, with zero whole-cache copies and
        an incremental pool allocation trail."""
        mono, paged = _pair(ARCHS["attn"])
        rng = np.random.RandomState(0)
        ctx = rng.randint(7, 512, size=(2, 56)).astype(np.int32)
        turn = rng.randint(7, 512, size=(2, 32)).astype(np.int32)
        r0 = mono.generate(ctx, 8, return_state=True)
        r1 = paged.generate(ctx, 8, return_state=True)
        allocs = [paged.pool.counters["blocks_alloc"]]
        for _ in range(4):                     # outgrows max_len=128
            r0 = mono.generate(turn, 8, state=r0["state"], return_state=True)
            r1 = paged.generate(turn, 8, state=r1["state"], return_state=True)
            np.testing.assert_array_equal(r0["tokens"], r1["tokens"])
            np.testing.assert_array_equal(np.asarray(r0["logits"]),
                                          np.asarray(r1["logits"]))
            allocs.append(paged.pool.counters["blocks_alloc"])
        assert mono.counters["grow_copy"] > 0      # monolithic did copy
        assert paged.counters["grow_copy"] == 0    # paged never does
        # growth allocated at most a dispatch-extension's worth of blocks
        # per turn (B rows x one 64-slot length bump), never a fresh
        # cache's worth
        per_turn = np.diff(allocs)
        assert (per_turn <= 2 * 2 * (64 // BLOCK)).all(), per_turn

    def test_session_trim_bounds_pool_usage(self):
        """A retired session keeps ceil(len/BLOCK) blocks, not the full
        dispatch run."""
        _, paged = _pair(ARCHS["attn"])
        st = paged.absorb(pad_prompts(PROMPTS)[:1])
        covered = st.cache.tables.shape[1]
        assert covered == -(-st.offset // BLOCK)
        assert covered * BLOCK < st.max_len   # physically < logical capacity
        paged.release(st)
        assert paged.pool.blocks_in_use == 0


class TestPrefixSharing:
    def test_fanout_issues_exactly_one_prefill(self):
        """One absorbed prefix fanned out to 8 slots: exactly 1 prefill
        dispatch total; the batched decode-only extension matches the
        monolithic tiled-state oracle bitwise."""
        mono, paged = _pair(ARCHS["attn"])
        ctx = pad_prompts(PROMPTS)[:1]
        st = paged.absorb(ctx)
        fan = paged.fanout(st, 8)
        out = paged.generate(None, 6, state=fan)
        assert paged.counters["prefill"] == 1
        assert paged.counters["prefill_continue"] == 0
        stm = mono.absorb(ctx)
        fanm = mono.state_select(stm, [0] * 8)
        ref = mono.generate(None, 6, state=fanm)
        np.testing.assert_array_equal(out["tokens"], ref["tokens"])
        np.testing.assert_array_equal(np.asarray(out["logits"]),
                                      np.asarray(ref["logits"]))

    @pytest.mark.parametrize("arch", ["attn", "rglru", "ssd"])
    def test_fanout_continuation_matches_cold_concat(self, arch):
        """Fan-out + per-slot divergent continuation == cold prefill of the
        concatenation, bitwise, for every mixer family."""
        mono, paged = _pair(ARCHS[arch])
        ctx = pad_prompts(PROMPTS)[:1]
        n = 4
        spans = pad_prompts([[30 + k, 31 + k, 2] for k in range(n)],
                            align="right")
        fan = paged.fanout(paged.absorb(ctx), n)
        warm = paged.generate(spans, 5, state=fan)
        assert paged.counters["prefill"] == 1
        cold = mono.generate(
            np.concatenate([np.tile(ctx, (n, 1)), spans], axis=1), 5)
        np.testing.assert_array_equal(warm["tokens"], cold["tokens"])
        np.testing.assert_array_equal(np.asarray(warm["logits"]),
                                      np.asarray(cold["logits"]))

    def test_shared_blocks_never_written_through(self):
        """COW guard: checksum the shared prefix blocks before and after
        divergent continuations — byte-identical (writes landed in COW'd
        tails and fresh blocks only)."""
        _, paged = _pair(ARCHS["rglru"])   # rglru+local attn: all pools
        ctx = pad_prompts(PROMPTS)[:1]
        st = paged.absorb(ctx)
        shared = np.asarray(st.cache.tables[0])

        def checksum():
            # pool leaves are (N, L, ...) or scan-stacked (repeat, N, L,
            # ...) — take the shared ids along the BLOCK axis
            ids = jnp.asarray(shared)
            vals = []
            for sc in paged.pool.arrays:
                for c in sc.values():
                    if c.kv is not None:
                        for leaf in c.kv:
                            if leaf is None:   # scale fields on bf16 pools
                                continue
                            # k/v are rank 4 (+1 stacked), pos rank 2 (+1)
                            base = (2 if jnp.issubdtype(leaf.dtype,
                                                        jnp.integer) else 4)
                            vals.append(np.asarray(jnp.take(
                                leaf, ids, axis=leaf.ndim - base)).copy())
            return vals

        before = checksum()
        fan = paged.fanout(st, 4)
        spans = pad_prompts([[40 + k, 2] for k in range(4)], align="right")
        paged.generate(spans, 6, state=fan)
        after = checksum()
        for b, a in zip(before, after):
            np.testing.assert_array_equal(b, a)
        assert paged.pool.counters["cow_copies"] >= 1

    def test_fanout_ring_wrap_cows_local_blocks(self):
        """A fork writing PAST the local-attention window wraps into the
        ring's FIRST blocks — which sit below the linear write position,
        i.e. in the shared prefix range.  COW must copy them per fork (the
        pool's ring_blocks rule) or divergent forks write through each
        other's local KV: continue a >window absorbed prefix with
        DIFFERENT spans per fork and compare bitwise against the
        monolithic tiled-state oracle."""
        mono, paged = _pair(ARCHS["rglru"])    # window=32, BLOCK=16
        rng = np.random.RandomState(3)
        ctx = rng.randint(7, 512, size=(1, 40)).astype(np.int32)
        n = 3
        spans = pad_prompts([[60 + 7 * k, 61 + 7 * k, 2] for k in range(n)],
                            align="right")     # divergent ring writes
        fan = paged.fanout(paged.absorb(ctx), n)
        out = paged.generate(spans, 8, state=fan, return_state=True)
        fanm = mono.state_select(mono.absorb(ctx), [0] * n)
        ref = mono.generate(spans, 8, state=fanm, return_state=True)
        np.testing.assert_array_equal(out["tokens"], ref["tokens"])
        np.testing.assert_array_equal(np.asarray(out["logits"]),
                                      np.asarray(ref["logits"]))
        # the write-through corruption only lands in the POOL — a second
        # dispatch off the forks reads it back (without the ring COW, the
        # duplicate scatter left one fork's ring content in the shared
        # blocks for everyone; observed as ~1e-2 logit corruption here)
        out2 = paged.generate(None, 8, state=out["state"])
        ref2 = mono.generate(None, 8, state=ref["state"])
        np.testing.assert_array_equal(out2["tokens"], ref2["tokens"])
        np.testing.assert_array_equal(np.asarray(out2["logits"]),
                                      np.asarray(ref2["logits"]))

    def test_serve_fans_shared_handle_across_requests(self):
        """N serve() requests carrying the SAME absorbed handle: zero extra
        prefill dispatches, each slot's decode == the session's own
        extension."""
        _, paged = _pair(ARCHS["attn"])
        ctx = pad_prompts(PROMPTS)[:1]
        st = paged.absorb(ctx)
        oracle = paged.generate(None, 6, state=paged.fanout(st, 1))
        assert paged.counters["prefill"] == 1
        fin = paged.serve([Request(rid=k, prompt=[], max_new=6, state=st)
                           for k in range(6)], n_slots=3, decode_chunk=3)
        assert paged.counters["prefill"] == 1      # still just the absorb
        assert len(fin) == 6
        for r in fin:
            np.testing.assert_array_equal(r["tokens"], oracle["tokens"][0])


class TestEvictionAndTTL:
    def test_released_handle_raises(self):
        _, paged = _pair(ARCHS["attn"])
        st = paged.absorb(pad_prompts(PROMPTS)[:1])
        paged.release(st)
        with pytest.raises(EvictedSessionError):
            paged.generate(None, 2, state=st)

    def test_ttl_eviction_invalidates_and_frees(self):
        _, paged = _pair(ARCHS["attn"])
        clock = [0.0]
        paged.pool._clock = lambda: clock[0]
        st = paged.absorb(pad_prompts(PROMPTS)[:1])
        held = paged.pool.blocks_in_use
        assert held > 0
        clock[0] = 100.0
        assert paged.evict_idle_sessions(ttl_s=50.0) == 1
        assert paged.pool.blocks_in_use == 0
        with pytest.raises(EvictedSessionError):
            paged.generate(None, 2, state=st)

    def test_evict_idle_spares_excluded_handles(self):
        """serve()'s famine recovery must not evict handles its own queued
        warm requests reference — evict_idle honours an exclusion set."""
        _, paged = _pair(ARCHS["attn"])
        clock = [0.0]
        paged.pool._clock = lambda: clock[0]
        st = paged.absorb(pad_prompts(PROMPTS)[:1])
        clock[0] = 100.0
        assert paged.pool.evict_idle(1.0, exclude={st.cache.sid}) == 0
        paged.pool.check(st.cache)               # still live (and touched)
        clock[0] = 200.0
        assert paged.pool.evict_idle(1.0) == 1

    def test_churn_keeps_high_water_bounded(self):
        """Sessions opened and TTL-evicted in a loop: the pool high-water
        mark stays bounded by one generation's working set instead of
        accumulating a run per session."""
        _, paged = _pair(ARCHS["attn"], pool_blocks=64)
        clock = [0.0]
        paged.pool._clock = lambda: clock[0]
        prompts = pad_prompts(PROMPTS)
        for it in range(12):
            paged.generate(prompts, 6, return_state=True)   # leaked session
            clock[0] += 10.0
            paged.evict_idle_sessions(ttl_s=5.0)
        one_gen = 3 * (128 // BLOCK)          # B=3 runs of max_len blocks
        assert paged.pool.counters["high_water"] <= 2 * one_gen
        assert paged.pool.blocks_in_use == 0

    def test_serve_pool_famine_defers_admission(self):
        """A pool sized for ~one slot still serves a deeper queue: vetoed
        admissions wait for retirements instead of failing."""
        _, paged = _pair(ARCHS["attn"], pool_blocks=2 * (128 // BLOCK),
                         pool_rows=4)
        prompts = pad_prompts(PROMPTS)
        res = paged.serve([Request(rid=i, prompt=prompts[i % 3].tolist(),
                                   max_new=4) for i in range(5)],
                          n_slots=4, decode_chunk=4)
        assert len(res) == 5
        assert paged.pool.blocks_in_use == 0


class TestDeadlineScheduler:
    def test_late_tight_deadline_preempts_queue_head(self):
        b = ContinuousBatcher(1)
        b.submit(Request(rid=0, prompt=[1], max_new=1))          # FIFO head
        b.submit(Request(rid=1, prompt=[1], max_new=1, deadline_ms=900.0))
        b.submit(Request(rid=2, prompt=[1], max_new=1, deadline_ms=100.0))
        assert b.admit() == [0]
        assert b.slots[0].rid == 2           # tightest deadline wins
        b.slots[0] = None
        b.admit()
        assert b.slots[0].rid == 1
        b.slots[0] = None
        b.admit()
        assert b.slots[0].rid == 0           # no-deadline request last

    def test_priority_breaks_deadline_ties_then_fifo(self):
        b = ContinuousBatcher(4)
        b.submit(Request(rid=0, prompt=[1], max_new=1, priority=5))
        b.submit(Request(rid=1, prompt=[1], max_new=1, priority=1))
        b.submit(Request(rid=2, prompt=[1], max_new=1, priority=1))
        b.submit(Request(rid=3, prompt=[1], max_new=1,
                         deadline_ms=10.0, priority=9))
        b.admit()
        assert [s.rid for s in b.slots] == [3, 1, 2, 0]

    def test_fits_veto_keeps_order(self):
        b = ContinuousBatcher(2)
        b.submit(Request(rid=0, prompt=[1], max_new=1, deadline_ms=1.0))
        b.submit(Request(rid=1, prompt=[1], max_new=1, deadline_ms=2.0))
        admitted = b.admit(fits=lambda r: r.rid == 1)
        assert [b.slots[i].rid for i in admitted] == [1]
        assert b.queue[0].rid == 0           # vetoed head stays queued

    def test_serve_deadline_order_end_to_end(self):
        """With 1 slot, completion order follows deadlines, not submit
        order, and the tokens are still the per-prompt generate stream."""
        _, paged = _pair(ARCHS["attn"])
        prompts = pad_prompts(PROMPTS)
        base = paged.generate(prompts, 4)
        reqs = [Request(rid=i, prompt=prompts[i].tolist(), max_new=4,
                        deadline_ms=float(1000 - 300 * i))
                for i in range(3)]
        fin = paged.serve(reqs, n_slots=1, decode_chunk=4)
        assert [r["rid"] for r in fin] == [2, 1, 0]
        for r in fin:
            np.testing.assert_array_equal(r["tokens"],
                                          base["tokens"][r["rid"]])


class TestServeSessions:
    def test_serve_handback_and_warm_readmission(self):
        """return_state hands a table-adopted handle back; re-serving it
        warm must be BITWISE the monolithic engine running the same
        two-serve sequence (same admission pattern — decode-interleaved
        multi-turn vs a single batched session is only tie-aware, see
        docs/RUNTIME.md numerics, so serve-to-serve is the exact oracle).
        Turn 1 itself is bitwise vs batched generate."""
        mono, paged = _pair(ARCHS["attn"])
        prompts = pad_prompts(PROMPTS)
        base = mono.generate(prompts, 4)

        def two_turns(eng):
            fin = eng.serve([Request(rid=i, prompt=prompts[i].tolist(),
                                     max_new=4, return_state=True)
                             for i in range(3)], n_slots=3, decode_chunk=4)
            states = {r["rid"]: r["state"] for r in fin}
            fin2 = eng.serve([Request(rid=i, prompt=SPANS[i], max_new=4,
                                      state=states[i]) for i in range(3)],
                             n_slots=3, decode_chunk=4)
            return fin, {r["rid"]: r["tokens"] for r in fin2}
        fin_m, warm_m = two_turns(mono)
        fin_p, warm_p = two_turns(paged)
        for r in fin_p:
            np.testing.assert_array_equal(r["tokens"],
                                          base["tokens"][r["rid"]])
        for rid in warm_m:
            np.testing.assert_array_equal(warm_p[rid], warm_m[rid])


class TestSwarmHandoff:
    def test_escalation_deepening_off_paged_probe(self):
        """The gateway handoff on a paged probe: state_select is a
        refcounted table copy, and the swarm round's escalation deepening
        extends decode-only — zero prefill dispatches beyond the probe's
        own generation, same deepened answers as a monolithic probe."""
        from repro.serving.swarm import SwarmExecutor
        mono, paged = _pair(ARCHS["attn"])
        prompts = pad_prompts(PROMPTS)
        rm = mono.generate(prompts, 4, return_state=True)
        rp = paged.generate(prompts, 4, return_state=True)
        idx = np.arange(len(PROMPTS))

        def deepen(probe, peer, res):
            pre = {0: (res["tokens"], res["u"],
                       (res["h_mean"], res["v_mean"]))}
            states = {0: probe.state_select(res["state"], idx)}
            return SwarmExecutor([probe, peer]).collaborate(
                prompts, 8, precomputed=pre, states=states)
        out_m = deepen(mono, mono, rm)
        out_p = deepen(paged, mono, rp)
        assert paged.counters["prefill"] == 1        # probe pass only
        np.testing.assert_array_equal(out_m["answers"], out_p["answers"])
        np.testing.assert_array_equal(out_p["answers"][:, 0, :4],
                                      rp["tokens"])


class TestPagedKernel:
    def _pool_case(self, B, K, G, D, N, L, nb, seed=0):
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 4)
        q = jax.random.normal(ks[0], (B, K, G, D), jnp.float32)
        k_pool = jax.random.normal(ks[1], (N, L, K, D), jnp.float32)
        v_pool = jax.random.normal(ks[2], (N, L, K, D), jnp.float32)
        table = jax.random.permutation(
            ks[3], np.arange(N))[:B * nb].reshape(B, nb).astype(jnp.int32)
        T_ = nb * L
        idx = jnp.asarray(np.linspace(T_ - 1, 3, B).astype(np.int32))
        lin = jnp.arange(T_)[None, :]
        pos_lin = jnp.where(lin <= idx[:, None], lin, -1).astype(jnp.int32)
        pos_pool = jnp.full((N, L), -1, jnp.int32)
        pos_pool = pos_pool.at[table.reshape(-1)].set(
            pos_lin.reshape(B * nb, L))
        return q, k_pool, v_pool, pos_pool, table, idx, pos_lin

    @pytest.mark.parametrize("window", [None, 16])
    def test_pallas_matches_refs(self, window):
        """Block-table kernel (interpret mode) == gathered-view oracle ==
        monolithic kernel on the equivalent linear layout."""
        from repro.kernels.decode_attention.ops import (
            decode_attention, paged_decode_attention)
        q, kp, vp, pp, table, idx, pos_lin = self._pool_case(
            B=3, K=2, G=4, D=16, N=14, L=8, nb=4)
        ref = paged_decode_attention(q, kp, vp, pp, table, idx,
                                     window=window)
        pal = paged_decode_attention(q, kp, vp, pp, table, idx,
                                     window=window, force_pallas=True)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                                   atol=2e-6, rtol=1e-6)
        B, nb, L = table.shape[0], table.shape[1], kp.shape[1]
        k_lin = kp[table.reshape(-1)].reshape(B, nb * L, *kp.shape[2:])
        v_lin = vp[table.reshape(-1)].reshape(B, nb * L, *vp.shape[2:])
        mono = decode_attention(q, k_lin, v_lin, pos_lin, idx,
                                window=window)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(mono))


# ---------------------------------------------------------------------------
# Multi-device sharded parity (subprocess — see test_prefill_parity.py on
# why the fake-device flag needs a fresh process)
# ---------------------------------------------------------------------------

PAGED_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, numpy as np
from repro import configs as C
from repro.core.uncertainty import UncertaintyConfig
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import Request
from repro.serving.swarm import pad_prompts
from repro.launch.mesh import serving_mesh

PROMPTS = [[3, 20, 195, 2], [3, 21, 196, 199, 2], [7, 9, 2], [5, 6, 7, 2]]
mesh = serving_mesh(model_parallel=2)
assert dict(mesh.shape) == {"data": 4, "model": 2}, mesh.shape
for arch in ("smollm-135m", "mamba2-780m"):
    cfg = dataclasses.replace(C.get_smoke(arch), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ucfg = UncertaintyConfig(mode="distribution")
    base = InferenceEngine(arch, cfg, params, ucfg)
    paged = InferenceEngine(arch, cfg, params, ucfg, paged=True,
                            block_len=16, pool_blocks=256, mesh=mesh)
    prompts = pad_prompts(PROMPTS)
    r0 = base.generate(prompts, 6)
    r1 = paged.generate(prompts, 6)
    # sharded reductions carry ~1 bf16 ulp vs single-device (same noise
    # class as the monolithic sharded path) -> compare tie-aware: greedy
    # streams agree except where the top-2 margin is inside that noise,
    # and only the prefix before a tie flip is comparable.
    l0, l1 = np.asarray(r0["logits"]), np.asarray(r1["logits"])
    for b in range(r0["tokens"].shape[0]):
        mism = np.where(r0["tokens"][b] != r1["tokens"][b])[0]
        n = mism[0] if len(mism) else r0["tokens"].shape[1]
        np.testing.assert_array_equal(r0["tokens"][b, :n],
                                      r1["tokens"][b, :n])
        np.testing.assert_allclose(l0[b, :n], l1[b, :n], atol=0.01, rtol=0)
        if len(mism):
            top2 = np.sort(l0[b, mism[0]])[-2:]
            assert top2[1] - top2[0] <= 0.02, (arch, b, mism[0], top2)
    if arch == "smollm-135m":
        fin = paged.serve([Request(rid=i, prompt=prompts[i].tolist(),
                                   max_new=6) for i in range(len(PROMPTS))],
                          n_slots=2, decode_chunk=3)
        assert len(fin) == len(PROMPTS)
        shard_only = InferenceEngine(arch, cfg, params, ucfg, mesh=mesh)
        rs = shard_only.generate(prompts, 6)
        # paged-sharded vs monolithic-sharded: same partitioned reductions
        # over elementwise-equal views -> identical greedy streams
        np.testing.assert_array_equal(r1["tokens"], rs["tokens"])
    print(arch, "ok", flush=True)
print("RESULT ok")
"""


def test_paged_sharded_matches_single_device():
    """Paged engine on a real (data=4, model=2) fake-device mesh: greedy
    parity with the single-device engine (tie-aware, like the monolithic
    sharded tests) and exact parity with the monolithic sharded engine."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", PAGED_SHARDED_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RESULT ok" in proc.stdout, proc.stdout
