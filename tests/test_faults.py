"""Failure-domain tests (docs/RUNTIME.md "Failure semantics"):

* fault-injection primitives — FaultPlan events, typed exceptions,
  RetryPolicy backoff, CircuitBreaker state machine, HealthRegistry
* serve()-level semantics — famine backpressure, forced eviction ->
  cold re-prefill, slot failure -> requeue, deadline expiry and
  priority preemption under injected stragglers, typed famine raise
* the famine -> TTL-evict -> retry regression (queued warm handles
  excluded from the sweep)
* swarm casualty salvage — consensus over survivors, straggle report
* session durability — checkpoint/restore across engine restarts and
  representations (paged <-> monolithic), resumed chat bitwise
* healthy-path parity — an empty FaultPlan changes nothing, bitwise
"""

import dataclasses
import tempfile

import jax
import numpy as np
import pytest

from repro import configs as C
from repro.core.uncertainty import UncertaintyConfig
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.faults import (CircuitBreaker, CloudUnavailableError,
                                  FaultEvent, FaultPlan, HealthRegistry,
                                  MemberDownError, PoolExhaustedError,
                                  RetryPolicy, ServingFault)
from repro.serving.scheduler import Request, select_peers
from repro.serving.swarm import SwarmExecutor, pad_prompts

BLOCK = 16
PROMPTS = [[3, 20, 195, 2], [3, 21, 196, 199, 2], [7, 9, 2]]


@pytest.fixture(scope="module")
def base():
    cfg = dataclasses.replace(C.get_smoke("smollm-135m"), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, UncertaintyConfig(mode="distribution")


def _engine(base, paged=True, **kw):
    cfg, params, ucfg = base
    if paged:
        kw.setdefault("block_len", BLOCK)
    return InferenceEngine("t", cfg, params, ucfg, paged=paged, **kw)


@pytest.fixture(scope="module")
def eng(base):
    return _engine(base, paged=True)


@pytest.fixture(scope="module")
def ref(eng):
    """Healthy batched generation — ground truth every fault path must
    still reproduce (greedy decode is deterministic)."""
    return eng.generate(pad_prompts(PROMPTS), 6)


def _reqs(max_new=6, **kw):
    return [Request(rid=i, prompt=list(PROMPTS[i]), max_new=max_new, **kw)
            for i in range(len(PROMPTS))]


class TestExceptions:
    def test_hierarchy(self):
        for exc in (MemberDownError, CloudUnavailableError,
                    PoolExhaustedError):
            assert issubclass(exc, ServingFault)
            assert issubclass(exc, RuntimeError)   # pre-existing handlers
        e = MemberDownError("down", member=3)
        assert e.member == 3 and e.delay_s == 0.0


class TestFaultPlan:
    def test_tick_gating_and_count(self):
        plan = FaultPlan([FaultEvent("cloud", "error", tick=2, count=2)])
        assert plan.consume("cloud") is None          # tick 0: not yet
        plan.tick(); plan.tick()
        assert plan.consume("cloud") is not None      # fires
        assert plan.consume("cloud") is not None      # count=2: fires again
        assert plan.consume("cloud") is None          # exhausted
        assert plan.counters == {"cloud:error": 2}

    def test_call_raises_typed(self):
        plan = FaultPlan([FaultEvent("cloud", "timeout", count=1),
                          FaultEvent("member:1", "crash", count=1)])
        with pytest.raises(CloudUnavailableError):
            plan.call("cloud", lambda: 42)
        with pytest.raises(MemberDownError) as ei:
            plan.call("member:1", lambda: 42)
        assert ei.value.member == 1
        # exhausted events: calls pass through, with zero delay
        assert plan.call("cloud", lambda: 42) == (42, 0.0)

    def test_straggle_reports_delay(self):
        plan = FaultPlan([FaultEvent("member:0", "straggle", count=1,
                                     delay_s=2.5)])
        out, delay = plan.call("member:0", lambda: "x")
        assert out == "x" and delay == 2.5

    def test_reset_restores_spec(self):
        plan = FaultPlan([FaultEvent("pool", "famine", count=1)], seed=7)
        draw = plan.rng.rand()
        assert plan.consume("pool") is not None
        assert plan.consume("pool") is None
        plan.tick()
        plan.reset()
        assert plan.now == 0
        assert plan.rng.rand() == draw                # rng re-seeded
        assert plan.consume("pool") is not None       # counts restored

    def test_random_is_seed_deterministic(self):
        a = FaultPlan.random(seed=3, n_members=4, ticks=20)
        b = FaultPlan.random(seed=3, n_members=4, ticks=20)
        sa = [(e.site, e.kind, e.tick, e.count, e.delay_s) for e in a.events]
        sb = [(e.site, e.kind, e.tick, e.count, e.delay_s) for e in b.events]
        assert sa == sb
        c = FaultPlan.random(seed=4, n_members=4, ticks=20)
        sc = [(e.site, e.kind, e.tick, e.count, e.delay_s) for e in c.events]
        assert sa != sc


class TestRetryPolicy:
    def test_backoff_grows_exponentially(self):
        p = RetryPolicy(backoff_base_s=0.5, backoff_mult=2.0, jitter=0.0)
        assert p.backoff(0) == 0.5
        assert p.backoff(1) == 1.0
        assert p.backoff(2) == 2.0

    def test_jitter_bounded_and_seeded(self):
        p = RetryPolicy(backoff_base_s=1.0, backoff_mult=2.0, jitter=0.25)
        rng = np.random.RandomState(0)
        draws = [p.backoff(0, rng) for _ in range(50)]
        assert all(0.75 <= d <= 1.25 for d in draws)
        assert len(set(draws)) > 1
        rng2 = np.random.RandomState(0)
        assert [p.backoff(0, rng2) for _ in range(50)] == draws


class TestCircuitBreaker:
    def test_state_cycle(self):
        br = CircuitBreaker(fail_threshold=1, cooldown_ticks=2)
        assert br.allow(1)
        br.record_failure(1)                 # trips: closed -> open
        assert br.opened_count == 1
        assert not br.allow(2)               # cooling down
        assert br.allow(3)                   # half-open probe
        br.record_failure(3)                 # probe failed -> re-open
        assert br.opened_count == 2
        assert not br.allow(4)
        assert br.allow(5)
        br.record_success()                  # probe succeeded -> closed
        assert br.allow(6)

    def test_threshold_needs_consecutive_failures(self):
        br = CircuitBreaker(fail_threshold=2, cooldown_ticks=2)
        br.record_failure(1)
        assert br.allow(2)                   # one failure: still closed
        br.record_success()
        br.record_failure(3)
        assert br.allow(4)                   # success reset the streak
        br.record_failure(4)
        assert not br.allow(5)


class TestHealthRegistry:
    def test_failure_threshold_and_probe(self):
        h = HealthRegistry(3, fail_threshold=2, probe_interval=3)
        assert h.available().all()
        h.record_failure(1)
        assert h.available().all()           # below threshold
        h.record_failure(1)
        assert h.available().tolist() == [True, False, True]
        # half-open probe: member 1 re-offered every probe_interval ticks
        probed = []
        for _ in range(6):
            h.tick()
            probed.append(bool(h.available()[1]))
        assert probed == [False, False, True, False, False, True]
        h.record_success(1)
        assert h.available().all()

    def test_ewma_latency(self):
        h = HealthRegistry(2, alpha=0.5)
        assert np.isnan(h.ewma).all()
        h.record_success(0, 1.0)
        h.record_success(0, 2.0)
        assert h.ewma[0] == pytest.approx(1.5)
        assert np.isnan(h.ewma[1])

    def test_select_peers_uses_health(self):
        pred = np.array([0.5, 0.2, 0.9, 0.3])
        h = HealthRegistry(4, fail_threshold=1)
        h.record_failure(1)                  # fastest peer is down
        mask = select_peers(pred, k=2, l_max=1.0, health=h)
        assert mask.tolist() == [True, False, False, True]
        # an observed slow EWMA displaces a good static prediction
        h2 = HealthRegistry(4)
        h2.record_success(1, 5.0)
        mask2 = select_peers(pred, k=2, l_max=1.0, health=h2)
        assert mask2.tolist() == [True, False, False, True]


class TestServeFaults:
    def test_famine_backpressure_still_answers(self, base, ref):
        e = _engine(base)
        plan = FaultPlan([FaultEvent("pool", "famine", count=3)])
        fin = e.serve(_reqs(), n_slots=2, decode_chunk=4, faults=plan)
        assert len(fin) == 3
        for r in fin:
            np.testing.assert_array_equal(r["tokens"], ref["tokens"][r["rid"]])
        assert e.counters["famine_deferred"] > 0
        assert plan.counters["pool:famine"] == 3

    def test_empty_plan_is_bitwise_noop(self, base):
        e = _engine(base)
        fin0 = e.serve(_reqs(), n_slots=2, decode_chunk=4, faults=None)
        c0 = dict(e.counters)
        fin1 = e.serve(_reqs(), n_slots=2, decode_chunk=4,
                       faults=FaultPlan([]))
        for a, b in zip(sorted(fin0, key=lambda r: r["rid"]),
                        sorted(fin1, key=lambda r: r["rid"])):
            np.testing.assert_array_equal(a["tokens"], b["tokens"])
            assert a["u"] == b["u"]
        for k in ("famine_deferred", "shed", "expired", "requeued",
                  "reprefill_cold"):
            assert e.counters[k] == c0[k]    # no fault counter moved

    def test_slot_failure_requeues(self, base, ref):
        e = _engine(base)
        plan = FaultPlan([FaultEvent("slot", "fail", count=1)])
        fin = e.serve(_reqs(), n_slots=2, decode_chunk=4, faults=plan)
        assert len(fin) == 3 and not any(r.get("shed") for r in fin)
        for r in fin:
            np.testing.assert_array_equal(r["tokens"], ref["tokens"][r["rid"]])
        assert e.counters["requeued"] == 1

    def test_forced_eviction_cold_reprefill(self, base):
        e = _engine(base)
        st = e.absorb(pad_prompts(PROMPTS[:1]))
        full = list(PROMPTS[0]) + [11, 12, 2]
        plan = FaultPlan([FaultEvent("session", "evict", count=1)])
        fin = e.serve([Request(rid=0, prompt=[11, 12, 2], state=st,
                               cold_prompt=full, max_new=6)],
                      n_slots=1, decode_chunk=6, faults=plan)
        cold = e.generate(pad_prompts([full]), 6)
        np.testing.assert_array_equal(fin[0]["tokens"], cold["tokens"][0])
        assert e.counters["reprefill_cold"] == 1

    def test_real_famine_typed_raise_and_shed(self, base):
        e = _engine(base, pool_blocks=4)     # absorb alone needs 8
        with pytest.raises(PoolExhaustedError):
            e.serve(_reqs()[:1], n_slots=1)
        fin = e.serve(_reqs()[:1], n_slots=1, overload="shed")
        assert fin[0]["shed"] and e.counters["shed"] == 1

    def test_deadline_expiry_under_straggler(self, base):
        # an injected decode straggle stalls the simulated clock past
        # rid 0's deadline while it waits in the queue -> expired+shed;
        # the unconstrained request rides out the stall and finishes
        e = _engine(base)
        plan = FaultPlan([FaultEvent("decode", "straggle", count=1,
                                     delay_s=10.0)])
        reqs = [Request(rid=0, prompt=list(PROMPTS[0]), max_new=20,
                        deadline_ms=5000.0),
                Request(rid=1, prompt=list(PROMPTS[1]), max_new=20)]
        fin = e.serve(reqs, n_slots=2, decode_chunk=4, faults=plan,
                      step_time_ms=10.0)
        shed = {r["rid"]: bool(r.get("shed")) for r in fin}
        assert shed == {0: True, 1: False}
        assert e.counters["expired"] == 1

    def test_priority_preemption_under_straggler(self, base):
        # one slot, straggler-stalled; among the queued requests the
        # urgent (lower priority value) one must be admitted first
        e = _engine(base)
        plan = FaultPlan([FaultEvent("decode", "straggle", count=1,
                                     delay_s=1.0)])
        reqs = [Request(rid=0, prompt=list(PROMPTS[0]), max_new=4),
                Request(rid=1, prompt=list(PROMPTS[1]), max_new=4,
                        priority=5),
                Request(rid=2, prompt=list(PROMPTS[2]), max_new=4,
                        priority=0)]
        fin = e.serve(reqs, n_slots=1, decode_chunk=4, faults=plan,
                      step_time_ms=1.0)
        order = [r["rid"] for r in fin]
        assert order.index(2) < order.index(1)
        assert len(fin) == 3 and not any(r.get("shed") for r in fin)


class TestFamineTTLEvictRetry:
    def test_ttl_sweep_spares_queued_warm_handles(self, base):
        # pool sized so two absorbed sessions wedge admission: without a
        # TTL the serve raises; with one, the idle session is evicted,
        # the retry admits, and the QUEUED warm request's handle survives
        # the sweep (it is served warm: prefill_continue, not cold)
        full_b = list(PROMPTS[1]) + [11, 2]

        def scenario(**kw):
            e = _engine(base, pool_blocks=9)
            e.absorb(pad_prompts(PROMPTS[:1]))          # idle -> evictable
            st_b = e.absorb(pad_prompts(PROMPTS[1:2]))  # queued warm ref
            reqs = [Request(rid=0, prompt=[11, 2], state=st_b,
                            cold_prompt=full_b, max_new=5),
                    Request(rid=1, prompt=list(PROMPTS[0]), max_new=5)]
            return e, e.serve(reqs, n_slots=2, decode_chunk=5, **kw)

        with pytest.raises(PoolExhaustedError):
            scenario()
        e, fin = scenario(session_ttl_s=0.0)
        warm_ref = e.generate(pad_prompts([full_b]), 5)
        cold_ref = e.generate(pad_prompts(PROMPTS[:1]), 5)
        for r in fin:
            exp = warm_ref if r["rid"] == 0 else cold_ref
            np.testing.assert_array_equal(r["tokens"], exp["tokens"][0])
        assert e.counters["reprefill_cold"] == 0   # handle NOT swept
        assert e.counters["prefill_continue"] >= 1


class TestSwarmCasualties:
    @pytest.fixture(scope="class")
    def mono(self, base):
        return _engine(base, paged=False)

    def test_crash_salvage(self, mono):
        prompts = pad_prompts(PROMPTS[:1])
        basep = SwarmExecutor([mono] * 3, stop_token=2).collaborate(prompts, 4)
        sw = SwarmExecutor([mono] * 3, stop_token=2,
                           faults=FaultPlan([FaultEvent("member:1", "crash",
                                                        count=1)]))
        res = sw.collaborate(prompts, 4)
        assert res["casualties"] == [1]
        assert (res["u"][:, 1] == 1.0).all()       # w_min sentinel row
        assert (res["answers"][:, 1] < 0).all()    # PAD
        # consensus renormalizes over the two survivors -> same winner
        np.testing.assert_array_equal(res["winner_tokens"],
                                      basep["winner_tokens"])

    def test_straggle_reported_not_dropped(self, mono):
        prompts = pad_prompts(PROMPTS[:1])
        basep = SwarmExecutor([mono] * 3, stop_token=2).collaborate(prompts, 4)
        sw = SwarmExecutor([mono] * 3, stop_token=2,
                           faults=FaultPlan([FaultEvent("member:2",
                                                        "straggle", count=1,
                                                        delay_s=3.0)]))
        res = sw.collaborate(prompts, 4)
        assert res["straggle_s"] == {2: 3.0}
        np.testing.assert_array_equal(res["answers"], basep["answers"])

    def test_empty_plan_parity(self, mono):
        prompts = pad_prompts(PROMPTS)
        a = SwarmExecutor([mono] * 3, stop_token=2).collaborate(prompts, 4)
        b = SwarmExecutor([mono] * 3, stop_token=2,
                          faults=FaultPlan([])).collaborate(prompts, 4)
        np.testing.assert_array_equal(a["answers"], b["answers"])
        np.testing.assert_array_equal(a["u"], b["u"])
        np.testing.assert_array_equal(a["winner_tokens"], b["winner_tokens"])
        assert b["casualties"] == [] and b["straggle_s"] == {}


class TestSessionDurability:
    @pytest.mark.parametrize("src_paged,dst_paged",
                             [(True, True), (True, False),
                              (False, True), (False, False)])
    def test_kill_rebuild_resume_bitwise(self, base, src_paged, dst_paged):
        turn2 = np.array([[9, 4, 2]], np.int32)
        e1 = _engine(base, paged=src_paged)
        st = e1.generate(pad_prompts(PROMPTS[:1]), 4,
                         return_state=True)["state"]
        with tempfile.TemporaryDirectory() as d:
            e1.checkpoint_session(st, d)
            ref = e1.generate(turn2, 4, state=st)    # uninterrupted chat
            e2 = _engine(base, paged=dst_paged)      # the "restarted" engine
            st2 = e2.restore_session(d)
            got = e2.generate(turn2, 4, state=st2)
        np.testing.assert_array_equal(got["tokens"], ref["tokens"])

    def test_restore_missing_and_wrong_kind(self, base):
        e = _engine(base, paged=False)
        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(FileNotFoundError):
                e.restore_session(d)
