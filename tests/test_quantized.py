"""Quantized serving tests (ISSUE 10).

``cache_quant="int8"|"fp8"`` stores the paged pool's KV blocks quantized
with per-block-row f32 scales and fuses the dequant into the decode
accumulator.  The contract is BUDGETED parity, not bitwise: against the
bf16 paged engine (itself bitwise vs monolithic), the quantized engine
must produce IDENTICAL greedy token streams and logits within a
per-arch budget — for every mixer family, through the whole session
API (cold generate, COW fanout, TTL churn, checkpoint/restore), and in
both decode paths (chunked-softmax and the Pallas block-table kernel).
Recurrent/conv state rows stay bf16 (pure-SSM archs are EXACT under
cache_quant).  ``weight_quant`` rides the same scheme for matmul
weights; MoE weight quantization is routing-sensitive, so its greedy
parity is only asserted where routing cannot flip.
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.core.uncertainty import UncertaintyConfig
from repro.models import quant as Q
from repro.models import transformer as T
from repro.serving.cache_manager import (EvictedSessionError,
                                         QuantMismatchError)
from repro.serving.engine import InferenceEngine
from repro.serving.swarm import pad_prompts

ARCHS = {
    "attn": "smollm-135m",
    "rglru": "recurrentgemma-2b",
    "ssd": "mamba2-780m",
    "moe_shared_routed": "deepseek-moe-16b",
    "moe_interleaved": "llama4-scout-17b-a16e",
}

# Per-arch max |logit| deltas vs the bf16 paged engine (~4x headroom
# over measured: attn/rglru <= 0.004, moe_sr <= 0.009, moe_il <= 0.053;
# ssd is a pure-SSM arch — no KV pool — and must be EXACT).  Documented
# in docs/RUNTIME.md "Quantized caches".
BUDGET = {
    "attn": 0.02, "rglru": 0.02, "ssd": 0.0,
    "moe_shared_routed": 0.05, "moe_interleaved": 0.2,
}

BLOCK = 16
PROMPTS = [[3, 20, 195, 2], [3, 21, 196, 199, 2], [7, 9, 2]]
SPANS = [[11, 12, 2], [13, 2], [14, 15, 16, 2]]


def _engine(arch, name="eng", **kw):
    cfg = kw.pop("cfg", None)
    if cfg is None:
        cfg = dataclasses.replace(C.get_smoke(arch), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return InferenceEngine(name, cfg, params,
                           UncertaintyConfig(mode="distribution"), **kw)


def _pair(arch, quant, **kw):
    cfg = dataclasses.replace(C.get_smoke(arch), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ucfg = UncertaintyConfig(mode="distribution")
    base = InferenceEngine("bf16", cfg, params, ucfg, paged=True,
                           block_len=BLOCK)
    qeng = InferenceEngine(quant, cfg, params, ucfg, paged=True,
                           block_len=BLOCK, cache_quant=quant, **kw)
    return base, qeng


def _assert_budgeted(r0, r1, budget):
    np.testing.assert_array_equal(r0["tokens"], r1["tokens"])
    l0 = np.asarray(r0["logits"], np.float32)
    l1 = np.asarray(r1["logits"], np.float32)
    np.testing.assert_allclose(l0, l1, atol=max(budget, 1e-7), rtol=0)


class TestBudgetedParity:
    @pytest.mark.parametrize("quant", ["int8", "fp8"])
    @pytest.mark.parametrize("arch", sorted(ARCHS))
    def test_generate_greedy_and_logit_budget(self, arch, quant):
        """Cold fused generate: same greedy stream, logits in budget,
        every mixer family and both quant formats."""
        base, qeng = _pair(ARCHS[arch], quant)
        prompts = pad_prompts(PROMPTS)
        r0 = base.generate(prompts, 6)
        r1 = qeng.generate(prompts, 6)
        _assert_budgeted(r0, r1, BUDGET[arch])

    def test_warm_continuation_in_budget(self):
        """absorb -> continue -> decode-only extend through a quantized
        pool: the session API stays in budget end to end."""
        base, qeng = _pair(ARCHS["attn"], "int8")
        prompts, span = pad_prompts(PROMPTS), pad_prompts(SPANS)
        w0 = base.generate(span, 6, state=base.absorb(prompts),
                           return_state=True)
        w1 = qeng.generate(span, 6, state=qeng.absorb(prompts),
                           return_state=True)
        _assert_budgeted(w0, w1, BUDGET["attn"])
        e0 = base.generate(None, 4, state=w0["state"])
        e1 = qeng.generate(None, 4, state=w1["state"])
        np.testing.assert_array_equal(e0["tokens"], e1["tokens"])

    def test_bf16_default_stays_bitwise_vs_monolithic(self):
        """The quantization machinery must not perturb the unquantized
        path: cache_quant=None paged == monolithic, bitwise."""
        mono = _engine(ARCHS["attn"], "mono")
        paged = _engine(ARCHS["attn"], "paged", paged=True, block_len=BLOCK)
        assert paged.pool.cache_quant is None
        prompts = pad_prompts(PROMPTS)
        r0 = mono.generate(prompts, 6)
        r1 = paged.generate(prompts, 6)
        np.testing.assert_array_equal(r0["tokens"], r1["tokens"])
        np.testing.assert_array_equal(np.asarray(r0["logits"]),
                                      np.asarray(r1["logits"]))

    def test_cache_quant_requires_paged(self):
        with pytest.raises(ValueError, match="paged"):
            _engine(ARCHS["attn"], cache_quant="int8")
        with pytest.raises(ValueError, match="quantization mode"):
            _engine(ARCHS["attn"], paged=True, cache_quant="int4")


class TestQuantizedKernel:
    def _quant_pool_case(self, quant, B=3, K=2, G=4, D=16, N=14, L=8, nb=4,
                         seed=0):
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 4)
        q = jax.random.normal(ks[0], (B, K, G, D), jnp.float32)
        k_pool = jax.random.normal(ks[1], (N, L, K, D), jnp.bfloat16)
        v_pool = jax.random.normal(ks[2], (N, L, K, D), jnp.bfloat16)
        kq, k_s = Q.quantize_rows(k_pool, quant)
        vq, v_s = Q.quantize_rows(v_pool, quant)
        table = jax.random.permutation(
            ks[3], np.arange(N))[:B * nb].reshape(B, nb).astype(jnp.int32)
        T_ = nb * L
        idx = jnp.asarray(np.linspace(T_ - 1, 3, B).astype(np.int32))
        lin = jnp.arange(T_)[None, :]
        pos_lin = jnp.where(lin <= idx[:, None], lin, -1).astype(jnp.int32)
        pos_pool = jnp.full((N, L), -1, jnp.int32)
        pos_pool = pos_pool.at[table.reshape(-1)].set(
            pos_lin.reshape(B * nb, L))
        return q, k_pool, v_pool, kq, vq, k_s, v_s, pos_pool, table, idx

    @pytest.mark.parametrize("window", [None, 16])
    @pytest.mark.parametrize("quant", ["int8", "fp8"])
    def test_pallas_fused_dequant_matches_oracle(self, quant, window):
        """Quantized block-table kernel (interpret mode) == the gathered
        dequantized-view oracle: the in-accumulator scale application is
        EXACT (a per-(slot,head) constant factors out of the Dh dot), so
        the two read strategies agree to f32 tolerance — and both sit
        within the format's error of the unquantized pool."""
        from repro.kernels.decode_attention.ops import paged_decode_attention
        (q, kp, vp, kq, vq, k_s, v_s, pp, table,
         idx) = self._quant_pool_case(quant)
        ref = paged_decode_attention(q, kq, vq, pp, table, idx,
                                     window=window, k_scale=k_s, v_scale=v_s)
        pal = paged_decode_attention(q, kq, vq, pp, table, idx,
                                     window=window, k_scale=k_s, v_scale=v_s,
                                     force_pallas=True)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                                   atol=2e-6, rtol=1e-6)
        full = paged_decode_attention(q, kp, vp, pp, table, idx,
                                      window=window)
        tol = 0.02 if quant == "int8" else 0.12
        np.testing.assert_allclose(np.asarray(pal), np.asarray(full),
                                   atol=tol, rtol=0)

    @pytest.mark.parametrize("quant", ["int8", "fp8"])
    def test_pallas_quantized_with_delta_overlay(self, quant):
        """Delta rows stay bf16 and overlay quantized pool slots: kernel
        == oracle with the two-phase read active."""
        from repro.kernels.decode_attention.ops import paged_decode_attention
        (q, _, _, kq, vq, k_s, v_s, pp, table,
         idx) = self._quant_pool_case(quant)
        B, S = table.shape[0], 4
        key = jax.random.PRNGKey(7)
        dk = jax.random.normal(key, (B, S) + kq.shape[2:], jnp.bfloat16)
        dv = jax.random.normal(jax.random.fold_in(key, 1),
                               (B, S) + kq.shape[2:], jnp.bfloat16)
        dpos = (idx[:, None] - jnp.arange(S, dtype=jnp.int32)[None, ::-1])
        p0 = jnp.maximum(idx - S + 1, 0)
        kw = dict(k_scale=k_s, v_scale=v_s, delta_k=dk, delta_v=dv,
                  delta_pos=dpos, p0=p0)
        ref = paged_decode_attention(q, kq, vq, pp, table, idx, **kw)
        pal = paged_decode_attention(q, kq, vq, pp, table, idx,
                                     force_pallas=True, **kw)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                                   atol=2e-6, rtol=1e-6)


class TestQuantizedPoolChurn:
    def test_cow_fanout_shared_blocks_and_scales_untouched(self):
        """COW on a quantized pool: divergent continuations never write
        through shared prefix blocks — payload OR scale leaves — and the
        fanned decode stays in budget vs the bf16 engine's fanout."""
        base, qeng = _pair(ARCHS["rglru"], "int8")   # all pool kinds
        ctx = pad_prompts(PROMPTS)[:1]
        st = qeng.absorb(ctx)
        shared = np.asarray(st.cache.tables[0])

        # rank below the block axis, per pool-leaf kind: k/v (N,L,K,Dh),
        # pos (N,L), scales (N,L,K) — int8 payloads break the dtype
        # heuristic the bf16 test uses, so key on the field name
        depth = {"k": 4, "v": 4, "pos": 2, "k_scale": 3, "v_scale": 3}

        def checksum():
            ids = jnp.asarray(shared)
            vals = []
            for sc in qeng.pool.arrays:
                for c in sc.values():
                    if c.kv is not None:
                        for fname, leaf in zip(c.kv._fields, c.kv):
                            if leaf is None:
                                continue
                            vals.append(np.asarray(jnp.take(
                                leaf, ids, axis=leaf.ndim - depth[fname])
                                .astype(jnp.float32)).copy())
            return vals

        # quantized pools actually carry scale leaves alongside k/v/pos
        assert all(c.kv is None or c.kv.k_scale is not None
                   for sc in qeng.pool.arrays for c in sc.values())
        before = checksum()
        spans = pad_prompts([[40 + k, 2] for k in range(4)], align="right")
        out = qeng.generate(spans, 6, state=qeng.fanout(st, 4))
        after = checksum()
        for b, a in zip(before, after):
            np.testing.assert_array_equal(b, a)
        assert qeng.pool.counters["cow_copies"] >= 1
        ref = base.generate(spans, 6, state=base.fanout(base.absorb(ctx), 4))
        _assert_budgeted(ref, out, BUDGET["rglru"])

    def test_ttl_eviction_and_cold_reprefill(self):
        """TTL-evicted quantized sessions free their blocks AND scale
        rows; a cold re-prefill of the same conversation lands on the
        recycled (reset) blocks and reproduces the same stream."""
        _, qeng = _pair(ARCHS["attn"], "int8")
        clock = [0.0]
        qeng.pool._clock = lambda: clock[0]
        prompts = pad_prompts(PROMPTS)
        st = qeng.absorb(prompts[:1])
        first = qeng.generate(None, 4, state=qeng.fanout(st, 1))
        clock[0] = 100.0
        assert qeng.evict_idle_sessions(ttl_s=50.0) >= 1
        assert qeng.pool.blocks_in_use == 0
        with pytest.raises(EvictedSessionError):
            qeng.generate(None, 2, state=st)
        st2 = qeng.absorb(prompts[:1])      # recycled blocks, reset scales
        again = qeng.generate(None, 4, state=qeng.fanout(st2, 1))
        np.testing.assert_array_equal(first["tokens"], again["tokens"])
        np.testing.assert_array_equal(np.asarray(first["logits"]),
                                      np.asarray(again["logits"]))

    def test_famine_message_reports_quantized_bytes(self):
        """Pool famine on a quantized engine names the quantized block
        bytes — capacity planning sees the real (reduced) footprint."""
        from repro.serving.cache_manager import PoolExhaustedError
        _, qeng = _pair(ARCHS["attn"], "int8", pool_blocks=8)
        bf16 = _engine(ARCHS["attn"], paged=True, block_len=BLOCK)
        assert qeng.pool.block_bytes < bf16.pool.block_bytes
        with pytest.raises(PoolExhaustedError, match=r"int8 blocks of"):
            qeng.pool.alloc(4, 16)


class TestQuantizedCheckpoint:
    def test_restore_round_trip_resumes_in_budget(self, tmp_path):
        """checkpoint -> fresh quantized engine -> restore: the saved
        linear view re-quantizes at scatter (scales recomputed over the
        same rows), and the resumed stream matches the unbroken session
        exactly."""
        _, qeng = _pair(ARCHS["attn"], "int8")
        prompts = pad_prompts(PROMPTS)
        r = qeng.generate(prompts, 4, return_state=True)
        unbroken = qeng.generate(None, 4, state=r["state"])
        qeng.checkpoint_session(r["state"], str(tmp_path), step=1)
        fresh = _engine(ARCHS["attn"], "fresh", paged=True, block_len=BLOCK,
                        cache_quant="int8")
        st = fresh.restore_session(str(tmp_path))
        resumed = fresh.generate(None, 4, state=st)
        np.testing.assert_array_equal(unbroken["tokens"], resumed["tokens"])
        np.testing.assert_allclose(
            np.asarray(unbroken["logits"], np.float32),
            np.asarray(resumed["logits"], np.float32),
            atol=BUDGET["attn"], rtol=0)

    @pytest.mark.parametrize("dst", ["mono", "bf16_paged", "fp8"])
    def test_representation_mismatch_raises_typed_error(self, tmp_path, dst):
        """A quantized checkpoint refuses to restore into ANY
        differently-represented engine (and vice versa): silent
        precision changes are an error, not a surprise."""
        _, qeng = _pair(ARCHS["attn"], "int8")
        r = qeng.generate(pad_prompts(PROMPTS), 3, return_state=True)
        qeng.checkpoint_session(r["state"], str(tmp_path), step=1)
        other = {
            "mono": dict(),
            "bf16_paged": dict(paged=True, block_len=BLOCK),
            "fp8": dict(paged=True, block_len=BLOCK, cache_quant="fp8"),
        }[dst]
        eng = _engine(ARCHS["attn"], dst, **other)
        with pytest.raises(QuantMismatchError, match="cache_quant='int8'"):
            eng.restore_session(str(tmp_path))

    def test_bf16_checkpoint_refused_by_quantized_engine(self, tmp_path):
        paged = _engine(ARCHS["attn"], "paged", paged=True, block_len=BLOCK)
        r = paged.generate(pad_prompts(PROMPTS), 3, return_state=True)
        paged.checkpoint_session(r["state"], str(tmp_path), step=1)
        _, qeng = _pair(ARCHS["attn"], "int8")
        with pytest.raises(QuantMismatchError, match="cache_quant=None"):
            qeng.restore_session(str(tmp_path))


class TestWeightQuant:
    @pytest.mark.parametrize("arch", ["attn", "rglru"])
    def test_dense_weight_quant_greedy_parity(self, arch):
        """int8 weights on dense archs: greedy stream identical, logits
        in (a slightly wider) budget.  Router/embed/norm/recurrent
        weights are exempt by design, so routing-free archs cannot
        flip."""
        cfg = dataclasses.replace(C.get_smoke(ARCHS[arch]), vocab_size=512)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        ucfg = UncertaintyConfig(mode="distribution")
        base = InferenceEngine("b", cfg, params, ucfg, paged=True,
                               block_len=BLOCK)
        w = InferenceEngine("w", cfg, params, ucfg, paged=True,
                            block_len=BLOCK, cache_quant="int8",
                            weight_quant="int8")
        r0 = base.generate(pad_prompts(PROMPTS), 6)
        r1 = w.generate(pad_prompts(PROMPTS), 6)
        _assert_budgeted(r0, r1, 2 * max(BUDGET[arch], 0.01))

    def test_weights_stored_quantized_on_device(self):
        _, _ = 0, 0
        eng = _engine(ARCHS["attn"], paged=True, block_len=BLOCK,
                      weight_quant="int8")
        leaves = jax.tree_util.tree_leaves(
            eng.params, is_leaf=lambda x: isinstance(x, Q.QTensor))
        qt = [l for l in leaves if isinstance(l, Q.QTensor)]
        assert qt, "no QTensor leaves after weight_quant"
        for t in qt:
            assert t.q.dtype == jnp.int8
            assert t.scale.dtype == jnp.float32
            assert t.scale.shape == t.q.shape[:-1]

    def test_moe_gather_impl_matches_dispatch_quantized(self):
        """gather-decode with QTensor experts: gathered rows dequantize
        AFTER the gather to the same values the dispatch einsums see, so
        the two impls agree to the pre-existing ~1 ulp einsum-order
        noise, now under quantized weights."""
        cfg = dataclasses.replace(C.get_smoke(ARCHS["moe_shared_routed"]),
                                  vocab_size=512)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        ucfg = UncertaintyConfig(mode="distribution")
        kw = dict(paged=True, block_len=BLOCK, cache_quant="int8",
                  weight_quant="int8")
        disp = InferenceEngine("disp", cfg, params, ucfg, **kw)
        cfg_g = dataclasses.replace(cfg, moe_decode_impl="gather")
        gath = InferenceEngine("gath", cfg_g, params, ucfg, **kw)
        r0 = disp.generate(pad_prompts(PROMPTS), 6)
        r1 = gath.generate(pad_prompts(PROMPTS), 6)
        np.testing.assert_allclose(np.asarray(r0["logits"], np.float32),
                                   np.asarray(r1["logits"], np.float32),
                                   atol=0.02, rtol=0)


SHARDED_QUANT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, numpy as np
from repro import configs as C
from repro.core.uncertainty import UncertaintyConfig
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.swarm import pad_prompts
from repro.launch.mesh import serving_mesh

PROMPTS = [[3, 20, 195, 2], [3, 21, 196, 199, 2], [7, 9, 2], [5, 6, 7, 2]]
mesh = serving_mesh(model_parallel=2)
cfg = dataclasses.replace(C.get_smoke("smollm-135m"), vocab_size=512)
params = T.init_params(cfg, jax.random.PRNGKey(0))
ucfg = UncertaintyConfig(mode="distribution")
base = InferenceEngine("b", cfg, params, ucfg, paged=True, block_len=16,
                       mesh=mesh)
q = InferenceEngine("q", cfg, params, ucfg, paged=True, block_len=16,
                    mesh=mesh, cache_quant="int8", weight_quant="int8")
prompts = pad_prompts(PROMPTS)
r0 = base.generate(prompts, 6)
r1 = q.generate(prompts, 6)
l0, l1 = np.asarray(r0["logits"], np.float32), np.asarray(r1["logits"],
                                                          np.float32)
# budgeted tie-aware: sharded reductions already carry ~1 ulp; compare
# the greedy prefix before any inside-budget tie flip
for b in range(r0["tokens"].shape[0]):
    mism = np.where(r0["tokens"][b] != r1["tokens"][b])[0]
    n = mism[0] if len(mism) else r0["tokens"].shape[1]
    np.testing.assert_array_equal(r0["tokens"][b, :n], r1["tokens"][b, :n])
    np.testing.assert_allclose(l0[b, :n], l1[b, :n], atol=0.05, rtol=0)
    if len(mism):
        top2 = np.sort(l0[b, mism[0]])[-2:]
        assert top2[1] - top2[0] <= 0.1, (b, mism[0], top2)
print("RESULT ok")
"""


def test_quantized_sharded_smoke():
    """Quantized pool + QTensor weights on the (4, 2) fake-device mesh:
    the scale sidecars shard with their pools (act_pool_scale) and the
    budgeted greedy parity holds under real partitioned reductions."""
    import subprocess
    import sys
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", SHARDED_QUANT_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RESULT ok" in proc.stdout, proc.stdout
