"""Golden-violation fixtures for every swarmlint rule (ISSUE 9).

Each AST rule has (a) a minimal fixture that MUST flag and (b) a
near-miss that MUST NOT — the near-misses are the idioms the serving
stack actually uses (donate-and-rebind, split-and-rebind, static-arg
branches, cfg.dtype allocation), so these tests pin the rules' false-
positive behaviour, not just their recall.  Pragma handling
(``# swarmlint: ignore[rule-id] justification``) is covered for the
same fixtures, and the abstract-eval probes run against the real tree
(they must stay green — the CI gate).
"""

import json
import subprocess
import sys

import pytest

from tools.swarmlint.rules import run_ast_rules


def _lint(tmp_path, source, relpath="serving/mod.py"):
    path = tmp_path / relpath
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(source)
    return run_ast_rules([str(path)])


def _active(findings, rule):
    return [f for f in findings if f.rule == rule and not f.suppressed]


# ---------------------------------------------------------------------------
# donation-reuse

DONATE_HEADER = """
import jax
from functools import partial

@partial(jax.jit, donate_argnames=("cache",))
def step(x, cache):
    return x + 1, cache
"""


class TestDonationReuse:
    def test_flags_reuse_after_donation(self, tmp_path):
        src = DONATE_HEADER + """
def caller(x, cache):
    y, new_cache = step(x, cache)
    return y + cache.sum()          # cache buffer is gone
"""
        fs = _active(_lint(tmp_path, src), "donation-reuse")
        assert len(fs) == 1 and "cache" in fs[0].message

    def test_flags_reuse_in_later_statement(self, tmp_path):
        src = DONATE_HEADER + """
def caller(x, cache):
    y, new_cache = step(x, cache)
    z = y * 2
    commit(cache)                   # still dead
"""
        assert len(_active(_lint(tmp_path, src), "donation-reuse")) == 1

    def test_near_miss_rebind_same_statement(self, tmp_path):
        src = DONATE_HEADER + """
def caller(x, cache):
    y, cache = step(x, cache)       # donate-and-rebind idiom
    return y + cache.sum()
"""
        assert _active(_lint(tmp_path, src), "donation-reuse") == []

    def test_near_miss_rebind_in_loop(self, tmp_path):
        src = DONATE_HEADER + """
def caller(x, cache):
    for _ in range(4):
        x, cache = step(x, cache)
    return x, cache
"""
        assert _active(_lint(tmp_path, src), "donation-reuse") == []

    def test_flags_cross_iteration_reuse(self, tmp_path):
        src = DONATE_HEADER + """
def caller(x, cache):
    for _ in range(4):
        x, _new = step(x, cache)    # cache dead on iteration 2
    return x
"""
        assert len(_active(_lint(tmp_path, src), "donation-reuse")) >= 1

    def test_near_miss_undonated_function(self, tmp_path):
        src = """
import jax
from functools import partial

@partial(jax.jit, static_argnames=("n",))
def step(x, cache, n):
    return x + n, cache

def caller(x, cache):
    y, new_cache = step(x, cache, 2)
    return y + cache.sum()          # no donation: reuse is fine
"""
        assert _active(_lint(tmp_path, src), "donation-reuse") == []


class TestDonationDup:
    def test_flags_duplicate_and_unknown_and_static(self, tmp_path):
        src = """
import jax
from functools import partial

@partial(jax.jit, donate_argnames=("cache", "cache"))
def a(x, cache):
    return x, cache

@partial(jax.jit, donate_argnames=("bogus",))
def b(x, cache):
    return x, cache

@partial(jax.jit, static_argnames=("cfg",), donate_argnames=("cfg",))
def c(x, cfg):
    return x
"""
        fs = _active(_lint(tmp_path, src), "donation-dup")
        msgs = "\n".join(f.message for f in fs)
        assert len(fs) == 3
        assert "more than once" in msgs and "not a parameter" in msgs \
            and "static" in msgs

    def test_near_miss_clean_declaration(self, tmp_path):
        src = DONATE_HEADER
        assert _active(_lint(tmp_path, src), "donation-dup") == []


# ---------------------------------------------------------------------------
# global-rng

class TestGlobalRng:
    def test_flags_np_global_and_stdlib(self, tmp_path):
        src = """
import random
import numpy as np

def admit(xs):
    np.random.seed(0)
    random.shuffle(xs)
    return np.random.randint(0, 4)
"""
        fs = _active(_lint(tmp_path, src), "global-rng")
        assert len(fs) == 3

    def test_near_miss_seeded_generators(self, tmp_path):
        src = """
import numpy as np

def admit(xs, seed):
    rs = np.random.RandomState(seed)       # owned, seeded: fine
    g = np.random.default_rng(seed)
    return rs.randint(0, 4) + int(g.integers(0, 4))
"""
        assert _active(_lint(tmp_path, src), "global-rng") == []

    def test_near_miss_outside_serving_dirs(self, tmp_path):
        src = """
import numpy as np

def make_dataset():
    np.random.seed(0)                      # benchmarks etc: allowed
    return np.random.randn(4)
"""
        fs = _lint(tmp_path, src, relpath="training/data.py")
        assert _active(fs, "global-rng") == []


# ---------------------------------------------------------------------------
# key-reuse

class TestKeyReuse:
    def test_flags_key_reused_across_iterations(self, tmp_path):
        src = """
import jax

def gen(rng, steps):
    out = []
    for _ in range(steps):
        out.append(sample(rng))            # same key every step
    return out
"""
        fs = _active(_lint(tmp_path, src), "key-reuse")
        assert len(fs) == 1 and "rng" in fs[0].message

    def test_flags_key_consumed_twice_sequentially(self, tmp_path):
        src = """
import jax

def gen(rng):
    a = sample(rng)
    b = sample(rng)                        # second draw, same key
    return a, b
"""
        assert len(_active(_lint(tmp_path, src), "key-reuse")) == 1

    def test_near_miss_split_and_rebind(self, tmp_path):
        src = """
import jax

def gen(rng, steps):
    out = []
    for _ in range(steps):
        rng, sub = jax.random.split(rng)   # consume-and-rebind idiom
        out.append(sample(sub))
    return out
"""
        assert _active(_lint(tmp_path, src), "key-reuse") == []

    def test_near_miss_carry_rebind(self, tmp_path):
        src = """
import jax

def serve(seed, chunks):
    rng = jax.random.PRNGKey(seed)
    for _ in range(chunks):
        toks, carry = decode(rng)
        cur, rng = carry                   # rebound from the carry
    return toks
"""
        assert _active(_lint(tmp_path, src), "key-reuse") == []

    def test_near_miss_split_into_key_array(self, tmp_path):
        src = """
import jax

def fan_out(rng, n):
    keys = jax.random.split(rng, n)        # key ARRAY: rows used one-off
    return [sample(keys[i]) for i in range(n)]
"""
        assert _active(_lint(tmp_path, src), "key-reuse") == []


# ---------------------------------------------------------------------------
# tracer-leak

class TestTracerLeak:
    def test_flags_branch_on_traced_value(self, tmp_path):
        src = """
import jax
from functools import partial

@partial(jax.jit, static_argnames=("greedy",))
def step(x, greedy):
    if x.sum() > 0:                        # traced condition
        return x
    return -x
"""
        fs = _active(_lint(tmp_path, src), "tracer-leak")
        assert len(fs) == 1 and "if" in fs[0].message

    def test_flags_host_conversions(self, tmp_path):
        src = """
import jax
import numpy as np

@jax.jit
def step(x):
    n = int(x[0])                          # host sync
    y = np.asarray(x)                      # host materialisation
    z = x.item()                           # device sync
    return n + z, y
"""
        assert len(_active(_lint(tmp_path, src), "tracer-leak")) == 3

    def test_near_miss_static_branches(self, tmp_path):
        src = """
import jax
from functools import partial

@partial(jax.jit, static_argnames=("greedy", "cfg"))
def step(x, greedy, cfg):
    B, S = x.shape
    if greedy:                             # static arg
        return x
    if S > 4:                              # shape is static
        return x * 2
    if cfg.window is not None:             # static arg attribute
        return x * 3
    n = int(x.shape[0])                    # shape access, not a tracer
    return x + n
"""
        assert _active(_lint(tmp_path, src), "tracer-leak") == []

    def test_near_miss_unjitted_function(self, tmp_path):
        src = """
def host_loop(x):
    if x.sum() > 0:                        # not jitted: fine
        return x
    return -x
"""
        assert _active(_lint(tmp_path, src), "tracer-leak") == []


# ---------------------------------------------------------------------------
# dtype-drift

class TestDtypeDrift:
    def test_flags_f32_cache_alloc(self, tmp_path):
        src = """
import jax.numpy as jnp

def init_cache(cfg, batch):
    return jnp.zeros((batch, 4), jnp.float32)
"""
        fs = _active(_lint(tmp_path, src, relpath="models/m.py"),
                     "dtype-drift")
        assert len(fs) == 1 and "float32" in fs[0].message

    def test_flags_missing_dtype(self, tmp_path):
        src = """
import jax.numpy as jnp

def init_cache(cfg, batch):
    return jnp.zeros((batch, 4))           # defaults to f32
"""
        assert len(_active(_lint(tmp_path, src, relpath="models/m.py"),
                           "dtype-drift")) == 1

    def test_near_miss_cfg_dtype_and_ints(self, tmp_path):
        src = """
import jax.numpy as jnp

def init_cache(cfg, batch):
    k = jnp.zeros((batch, 4), cfg.dtype)
    pos = jnp.full((batch,), -1, jnp.int32)
    return k, pos
"""
        assert _active(_lint(tmp_path, src, relpath="models/m.py"),
                       "dtype-drift") == []

    def test_near_miss_non_init_function(self, tmp_path):
        src = """
import jax.numpy as jnp

def softmax_stream(x):
    acc = jnp.zeros(x.shape, jnp.float32)  # one-step accumulator: fine
    return acc
"""
        assert _active(_lint(tmp_path, src, relpath="models/m.py"),
                       "dtype-drift") == []


# ---------------------------------------------------------------------------
# quant-scale-drift

class TestQuantScaleDrift:
    def test_flags_narrow_scale_alloc(self, tmp_path):
        src = """
import jax.numpy as jnp

def grow_pool(n, L, K):
    k_scale = jnp.zeros((n, L, K), jnp.bfloat16)
    return k_scale
"""
        fs = _active(_lint(tmp_path, src, relpath="serving/m.py"),
                     "quant-scale-drift")
        assert len(fs) == 1 and "float32" in fs[0].message

    def test_flags_scale_cast_narrow(self, tmp_path):
        src = """
import jax.numpy as jnp

def pack(pool):
    return pool.k_scale.astype(jnp.bfloat16)
"""
        assert len(_active(_lint(tmp_path, src, relpath="models/m.py"),
                           "quant-scale-drift")) == 1

    def test_flags_f32_dequantize_rows(self, tmp_path):
        src = """
import jax.numpy as jnp
from repro.models.quant import dequantize_rows

def view(q, scale):
    return dequantize_rows(q, scale, jnp.float32)
"""
        fs = _active(_lint(tmp_path, src, relpath="serving/m.py"),
                     "quant-scale-drift")
        assert len(fs) == 1 and "accumulator" in fs[0].message

    def test_flags_manual_f32_dequant_multiply(self, tmp_path):
        src = """
import jax.numpy as jnp

def attend(q_rows, k_scale):
    k = q_rows.astype(jnp.float32) * k_scale[..., None]
    return k
"""
        assert len(_active(_lint(tmp_path, src, relpath="models/m.py"),
                           "quant-scale-drift")) == 1

    def test_near_miss_accumulator_fused_scale(self, tmp_path):
        # the sanctioned fused-dequant shape: scores already f32 from
        # preferred_element_type, scale applied WITHOUT an .astype(f32)
        src = """
import jax.numpy as jnp

def stream_chunk(s, k_s, v_s, p):
    s = s * k_s.transpose(0, 2, 1)[:, :, None, :]
    p = p * v_s.transpose(0, 2, 1)[:, :, None, :]
    return s, p
"""
        assert _active(_lint(tmp_path, src, relpath="models/m.py"),
                       "quant-scale-drift") == []

    def test_near_miss_f32_scale_alloc_and_cache_dtype_dequant(self, tmp_path):
        src = """
import jax.numpy as jnp
from repro.models.quant import dequantize_rows

def grow_pool(n, L, K, view_dtype):
    v_scale = jnp.zeros((n, L, K), jnp.float32)  # swarmlint: ignore[dtype-drift] scales are f32 by contract
    return v_scale

def view(q, scale, view_dtype):
    return dequantize_rows(q, scale, view_dtype)
"""
        assert _active(_lint(tmp_path, src, relpath="serving/m.py"),
                       "quant-scale-drift") == []

    def test_near_miss_outside_serving_dirs(self, tmp_path):
        src = """
import jax.numpy as jnp

def plot(q, scale):
    return q.astype(jnp.float32) * scale
"""
        assert _active(_lint(tmp_path, src, relpath="benchmarks/b.py"),
                       "quant-scale-drift") == []


# ---------------------------------------------------------------------------
# pragmas

class TestPragmas:
    FLAGGING = """
import jax.numpy as jnp

def init_cache(cfg, batch):
    return jnp.zeros((batch, 4), jnp.float32){pragma}
"""

    def test_same_line_pragma_suppresses(self, tmp_path):
        src = self.FLAGGING.format(
            pragma="  # swarmlint: ignore[dtype-drift] f32 accumulator")
        fs = _lint(tmp_path, src, relpath="models/m.py")
        assert _active(fs, "dtype-drift") == []
        sup = [f for f in fs if f.suppressed]
        assert len(sup) == 1 and sup[0].justification == "f32 accumulator"

    def test_standalone_pragma_suppresses_next_code_line(self, tmp_path):
        src = """
import jax.numpy as jnp

def init_cache(cfg, batch):
    # swarmlint: ignore[dtype-drift] recurrence drifts in bf16
    # (continuation comment lines are skipped)
    return jnp.zeros((batch, 4), jnp.float32)
"""
        fs = _lint(tmp_path, src, relpath="models/m.py")
        assert _active(fs, "dtype-drift") == []

    def test_pragma_without_justification_is_bad_and_inert(self, tmp_path):
        src = self.FLAGGING.format(pragma="  # swarmlint: ignore[dtype-drift]")
        fs = _lint(tmp_path, src, relpath="models/m.py")
        assert len(_active(fs, "dtype-drift")) == 1      # not suppressed
        assert len(_active(fs, "bad-pragma")) == 1

    def test_pragma_for_other_rule_does_not_suppress(self, tmp_path):
        src = self.FLAGGING.format(
            pragma="  # swarmlint: ignore[key-reuse] wrong rule id")
        fs = _lint(tmp_path, src, relpath="models/m.py")
        assert len(_active(fs, "dtype-drift")) == 1


# ---------------------------------------------------------------------------
# the real tree + the probes (the CI gate)

class TestRepoIsClean:
    def test_ast_rules_green_on_src(self):
        fs = [f for f in run_ast_rules(["src/repro"]) if not f.suppressed]
        assert fs == [], "\n".join(f"{f.location()} {f.rule} {f.message}"
                                   for f in fs)

    def test_every_suppression_has_a_justification(self):
        for f in run_ast_rules(["src/repro"]):
            if f.suppressed:
                assert f.justification, f.location()

    def test_cheap_probes_green(self):
        # shard-coverage walks config metadata; pallas-grid is pure python
        from tools.swarmlint.probes import run_probes
        fs = run_probes(only={"shard-coverage", "pallas-grid"})
        assert fs == [], "\n".join(f.message for f in fs)

    @pytest.mark.slow
    def test_abstract_probes_green(self):
        # decode-dtype eval-shapes every arch; donation-alias lowers the
        # paged entry points — slower, still device-free
        from tools.swarmlint.probes import run_probes
        fs = run_probes(only={"decode-dtype", "donation-alias"})
        assert fs == [], "\n".join(f.message for f in fs)

    def test_cli_json_output(self):
        out = subprocess.run(
            [sys.executable, "-m", "tools.swarmlint", "--no-probes",
             "--json"],
            capture_output=True, text=True)
        assert out.returncode == 0, out.stdout + out.stderr
        payload = json.loads(out.stdout)
        assert payload["counts"]["active"] == 0
        assert payload["counts"]["suppressed"] >= 5
