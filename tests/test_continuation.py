"""Continuation-prefill + session-cache tests (docs/RUNTIME.md).

The contract under test:

* **Continuation parity** — absorbing a context (prefill-only), then
  continuation-prefilling a new span over the live cache, is BITWISE
  identical (greedy tokens AND logits) to cold-prefilling the
  concatenation — for all three mixer families and both MoE archs,
  unsharded and on the degenerate (1, 1) serving mesh (the real (4, 2)
  mesh runs in test_prefill_parity's subprocess).
* **Decode extension** — resuming from a session's pending token emits
  exactly the tokens a longer original generation would have produced
  next (pure decode: bitwise by construction).
* **Multi-turn sessions** — turn t+1 continues turn t's cache.  Decode
  steps write K/V with one-token projections, so a cold re-prefill of the
  whole conversation regroups those matmuls: logits agree to ~1 bf16 ulp
  and greedy tokens match except on sub-ulp top-2 ties (the same noise
  class RUNTIME.md documents for ``moe_decode_impl="gather"``) — the
  comparison below is tie-aware.
* **serve()** — warm admissions splice the session cache and prefill only
  the new span; ``return_state=True`` round-trips a request's session.
* **Answer normalisation bugfixes** — the edge/cloud baselines grade
  truncated answers exactly like the gateway, and streaming swarm rounds
  retire at the stop token and agree with batched rounds on winners AND u.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import configs as C
from repro.core.uncertainty import UncertaintyConfig
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import Request
from repro.serving.swarm import SwarmExecutor, pad_prompts, truncate_at_stop

ARCHS = {
    "attn": "smollm-135m",
    "rglru": "recurrentgemma-2b",
    "ssd": "mamba2-780m",
    "moe-topk-shared": "deepseek-moe-16b",
    "moe-top1-shared": "llama4-scout-17b-a16e",
}

CTX = [[3, 20, 195, 2, 9, 31], [3, 21, 196, 199, 2, 7], [7, 9, 2, 44, 45, 2]]
SPAN = [[11, 12, 2], [13, 2], [14, 15, 16, 2]]
SPAN2 = [[33, 2], [34, 35, 2], [36, 2]]


def _engine(arch: str, mesh=None, max_len: int = 128) -> InferenceEngine:
    cfg = dataclasses.replace(C.get_smoke(arch), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return InferenceEngine(arch, cfg, params,
                           UncertaintyConfig(mode="distribution"),
                           mesh=mesh, max_len=max_len)


@pytest.fixture(scope="module", params=sorted(ARCHS))
def engine(request):
    return _engine(ARCHS[request.param])


def _assert_greedy_match_modulo_ties(warm: dict, cold: dict,
                                     atol: float = 0.01):
    """Greedy streams must agree except where the cold top-2 margin is
    below bf16 activation noise; once a tie flips, the histories diverge
    legitimately, so only the prefix up to the first mismatch is compared.
    (Mirrored inline in test_prefill_parity's SHARDED_SCRIPT — the
    subprocess can't import the tests package; keep the two in sync.)"""
    tw, tc = warm["tokens"], cold["tokens"]
    lw, lc = np.asarray(warm["logits"]), np.asarray(cold["logits"])
    for b in range(tw.shape[0]):
        mism = np.where(tw[b] != tc[b])[0]
        n = mism[0] if len(mism) else tw.shape[1]
        np.testing.assert_array_equal(tw[b, :n], tc[b, :n])
        np.testing.assert_allclose(lw[b, :n], lc[b, :n], atol=atol, rtol=0)
        if len(mism):
            top2 = np.sort(lc[b, mism[0]])[-2:]
            assert top2[1] - top2[0] <= 2 * atol, \
                f"row {b}: token flip with margin {top2[1] - top2[0]}"


class TestContinuationParity:
    def test_warm_continuation_bitwise_matches_cold_concat(self, engine):
        """absorb(ctx) then generate(span, state=...) == generate([ctx;span])
        bitwise — tokens AND logits."""
        ctx, span = pad_prompts(CTX), pad_prompts(SPAN)
        st = engine.absorb(ctx)
        warm = engine.generate(span, 6, state=st)
        cold = engine.generate(np.concatenate([ctx, span], axis=1), 6)
        np.testing.assert_array_equal(warm["tokens"], cold["tokens"])
        np.testing.assert_array_equal(np.asarray(warm["logits"]),
                                      np.asarray(cold["logits"]))
        np.testing.assert_allclose(warm["u"], cold["u"], atol=1e-6)

    def test_absorb_then_extend_matches_generate(self, engine):
        """A session's pending token is the prefill argmax: decode-only
        extension off an absorbed context replays generate() bitwise."""
        ctx = pad_prompts(CTX)
        ext = engine.generate(None, 6, state=engine.absorb(ctx))
        base = engine.generate(ctx, 6)
        np.testing.assert_array_equal(ext["tokens"], base["tokens"])

    def test_extension_resumes_bitwise(self, engine):
        """generate(N) + extend(K) == generate(N + K): the decode scan is
        sequential, so resuming from the carry replays the same steps."""
        ctx = pad_prompts(CTX)
        r1 = engine.generate(ctx, 4, return_state=True)
        ext = engine.generate(None, 4, state=r1["state"])
        long = engine.generate(ctx, 8)
        np.testing.assert_array_equal(
            np.concatenate([r1["tokens"], ext["tokens"]], axis=1),
            long["tokens"])

    def test_multiturn_sessions_match_cold_reprefill(self, engine):
        """Three turns over one session vs cold re-prefill of the growing
        conversation (tie-aware: decode-written K/V carry ~1 ulp)."""
        ctx = pad_prompts(CTX)
        hist = ctx
        r = engine.generate(ctx, 4, return_state=True)
        for span_toks in (SPAN, SPAN2):
            span = pad_prompts(span_toks)
            hist = np.concatenate([hist, r["tokens"], span], axis=1)
            r = engine.generate(span, 4, state=r["state"], return_state=True)
            cold = engine.generate(hist, 4)
            _assert_greedy_match_modulo_ties(r, cold)
            if np.array_equal(r["tokens"], cold["tokens"]):
                np.testing.assert_allclose(r["u"], cold["u"], atol=1e-4)

    def test_session_cache_growth(self):
        """A session that outgrows its cache is grown in place (new empty
        slots) — continuation stays bitwise vs the cold concatenation."""
        eng = _engine(ARCHS["attn"], max_len=16)
        ctx, span = pad_prompts(CTX), pad_prompts(SPAN)
        st = eng.absorb(ctx)
        assert st.max_len == 16
        warm = eng.generate(span, 8, state=st, return_state=True)
        assert warm["state"].max_len > 16
        cold = eng.generate(np.concatenate([ctx, span], axis=1), 8)
        np.testing.assert_array_equal(warm["tokens"], cold["tokens"])

    def test_degenerate_mesh_warm_is_bitwise_identical(self):
        """The mesh-sharded continuation path on the (1, 1) serving mesh is
        bit-for-bit the unsharded one (warm caches keep their cache_axes
        shardings through generate/extend)."""
        from repro.launch.mesh import serving_mesh
        for arch in (ARCHS["attn"], ARCHS["rglru"], ARCHS["ssd"],
                     ARCHS["moe-topk-shared"]):
            base = _engine(arch)
            shard = InferenceEngine(arch, base.cfg, base.params, base.ucfg,
                                    mesh=serving_mesh())
            ctx, span = pad_prompts(CTX), pad_prompts(SPAN)
            r0 = base.generate(span, 6, state=base.absorb(ctx),
                               return_state=True)
            r1 = shard.generate(span, 6, state=shard.absorb(ctx),
                                return_state=True)
            np.testing.assert_array_equal(r0["tokens"], r1["tokens"])
            np.testing.assert_array_equal(np.asarray(r0["logits"]),
                                          np.asarray(r1["logits"]))
            e0 = base.generate(None, 4, state=r0["state"])
            e1 = shard.generate(None, 4, state=r1["state"])
            np.testing.assert_array_equal(e0["tokens"], e1["tokens"])


class TestServeSessions:
    def test_warm_admission_matches_generate(self):
        """serve() with Request.state splices the session cache and
        continuation-prefills only the new span; tokens match the batched
        warm generate bitwise."""
        eng = _engine(ARCHS["attn"])
        prompts = pad_prompts(CTX)
        r1 = eng.generate(prompts, 6, return_state=True)
        spans = SPAN
        reqs = [Request(rid=i, prompt=spans[i], max_new=6,
                        state=eng.state_select(r1["state"], [i]))
                for i in range(3)]
        pre_cold = eng.counters["prefill"]
        fin = eng.serve(reqs, n_slots=2, decode_chunk=4)
        assert eng.counters["prefill"] == pre_cold  # zero cold prefills
        ref = eng.generate(pad_prompts(spans), 6, state=r1["state"])
        for r in fin:
            np.testing.assert_array_equal(r["tokens"], ref["tokens"][r["rid"]])

    def test_return_state_roundtrip_through_serve(self):
        """Multi-turn over serve(): turn 1 hands back per-request states,
        turn 2 admits them warm; both turns match the batched session."""
        eng = _engine(ARCHS["attn"])
        prompts = pad_prompts(CTX)
        fin1 = eng.serve([Request(rid=i, prompt=prompts[i].tolist(),
                                  max_new=6, return_state=True)
                          for i in range(3)], n_slots=2, decode_chunk=4)
        states = {r["rid"]: r["state"] for r in fin1}
        assert len(states) == 3
        fin2 = eng.serve([Request(rid=i, prompt=SPAN[i], max_new=6,
                                  state=states[i]) for i in range(3)],
                         n_slots=2, decode_chunk=4)
        r1 = eng.generate(prompts, 6, return_state=True)
        r2 = eng.generate(pad_prompts(SPAN), 6, state=r1["state"])
        for r in fin1:
            np.testing.assert_array_equal(r["tokens"], r1["tokens"][r["rid"]])
        for r in fin2:
            np.testing.assert_array_equal(r["tokens"], r2["tokens"][r["rid"]])

    def test_return_state_chunk_clamped_for_recurrent_mixers(self):
        """decode_chunk larger than max_new: the chunk is clamped so the
        recurrent slot state is captured exactly at the request's last
        step — the round-tripped state extends bitwise."""
        eng = _engine(ARCHS["ssd"])
        prompts = pad_prompts(CTX)
        fin = eng.serve([Request(rid=i, prompt=prompts[i].tolist(),
                                 max_new=5, return_state=True)
                         for i in range(3)], n_slots=3, decode_chunk=8)
        r1 = eng.generate(prompts, 5, return_state=True)
        ref = eng.generate(None, 4, state=r1["state"])
        for r in sorted(fin, key=lambda r: r["rid"]):
            ext = eng.generate(None, 4, state=r["state"])
            np.testing.assert_array_equal(ext["tokens"][0],
                                          ref["tokens"][r["rid"]])


    def test_continuation_span_longer_than_window(self):
        """A continuation span that overflows a local-attention window must
        keep the LAST window of real K/V: spans are right-padded, so the
        ring trim goes by position, not by column (a column slice would
        keep bucket padding and drop the most recent real tokens)."""
        eng = _engine(ARCHS["rglru"])       # attn_local window = 32 smoke
        ctx = pad_prompts(CTX)
        span = pad_prompts([list(range(50, 90))] * 3)   # 40 real > window
        assert span.shape[1] > eng.cfg.window
        warm = eng.generate(span, 6, state=eng.absorb(ctx))
        cold = eng.generate(np.concatenate([ctx, span], axis=1), 6)
        np.testing.assert_array_equal(warm["tokens"], cold["tokens"])

    def test_sampled_extension_resumes_rng_stream_bitwise(self):
        """The session carries the decode scan's rng, so greedy=False
        extension also replays a longer generation bitwise."""
        eng = _engine(ARCHS["attn"])
        ctx = pad_prompts(CTX)
        r1 = eng.generate(ctx, 4, greedy=False, seed=11, return_state=True)
        ext = eng.generate(None, 4, state=r1["state"], greedy=False)
        long = eng.generate(ctx, 8, greedy=False, seed=11)
        np.testing.assert_array_equal(
            np.concatenate([r1["tokens"], ext["tokens"]], axis=1),
            long["tokens"])

    def test_nondivisible_max_len_is_rounded_for_warm_attention(self):
        """A constructor max_len the KV block doesn't divide would break
        the warm path's chunked attention over the cache; the engine
        rounds it up (smoke kv_block=32: 100 -> 128)."""
        eng = _engine(ARCHS["attn"], max_len=100)
        assert eng.max_len % eng.cfg.attn_kv_block == 0
        ctx, span = pad_prompts(CTX), pad_prompts(SPAN)
        warm = eng.generate(span, 6, state=eng.absorb(ctx))
        cold = eng.generate(np.concatenate([ctx, span], axis=1), 6)
        np.testing.assert_array_equal(warm["tokens"], cold["tokens"])

    def test_midchunk_stop_retirement_marks_state_inexact(self):
        """A return_state request retiring at a stop token mid-chunk gets
        an inexact handle: the slot kept decoding garbage past the stop.
        Extension refuses it (corrupted pending token); so does any reuse
        on a recurrent-mixer model; attention-only continuation prefill is
        allowed (stale KV entries are masked until overwritten)."""
        for arch, recurrent in ((ARCHS["attn"], False), (ARCHS["ssd"], True)):
            eng = _engine(arch)
            prompts = pad_prompts(CTX)
            stop = int(eng.generate(prompts, 6)["tokens"][0, 1])
            fin = eng.serve([Request(rid=0, prompt=prompts[0].tolist(),
                                     max_new=6, return_state=True)],
                            n_slots=1, decode_chunk=6, stop_token=stop)
            st = fin[0]["state"]
            if len(fin[0]["tokens"]) == 6:
                continue        # stop never fired for this arch: no claim
            assert not st.exact
            with pytest.raises(ValueError, match="inexact"):
                eng.generate(None, 4, state=st)
            with pytest.raises(ValueError, match="inexact"):
                eng.serve([Request(rid=1, prompt=[], max_new=2, state=st)],
                          n_slots=1)
            if recurrent:
                with pytest.raises(ValueError, match="inexact"):
                    eng.generate(pad_prompts([SPAN[0]]), 4, state=st)
            else:
                out = eng.generate(pad_prompts([SPAN[0]]), 4, state=st)
                assert out["tokens"].shape == (1, 4)


class TestAnswerNormalisation:
    """Regression tests for the two Table III/IV normalisation bugs."""

    def test_baselines_grade_truncated_answers(self):
        """run_edge_only/run_cloud_only must apply truncate_at_stop before
        grading: a gold entity appearing only AFTER the stop token is not
        an answer (the gateway never counts it), and the logged answers
        must be the truncated ones."""
        from repro.serving.gateway import run_cloud_only, run_edge_only
        from repro.serving.simulator import NetworkSimulator, SimConfig
        from repro.core.cost_model import LatencyParams

        sim = NetworkSimulator(SimConfig(), LatencyParams(), 1)
        stop, gold_pre, gold_post = 9, 5, 301
        row = np.array([gold_pre, stop, 7, gold_post, 7, 2], np.int32)

        class _ScriptedEngine:
            """Generation stub: the regression targets the baselines'
            grading pipeline, not the model."""

            def generate(self, prompts, max_new, seed=0):
                B = prompts.shape[0]
                return {"tokens": np.tile(row[:max_new], (B, 1)),
                        "u": np.zeros((B,), np.float32), "logits": None}

        queries = [{"prompt": CTX[0], "gold": gold_post},
                   {"prompt": CTX[0], "gold": gold_pre}]
        for runner in (run_edge_only,
                       lambda q, e, s, **kw: run_cloud_only(q, e, s, **kw)):
            log = runner(queries, _ScriptedEngine(), sim, max_new=6,
                         stop_token=stop)
            np.testing.assert_array_equal(
                log.answers, truncate_at_stop(np.stack([row, row]), stop))
            assert not log.correct[0]      # gold only after the stop token
            assert log.correct[1]          # gold before it still counts
            # pre-fix behaviour: raw tokens would have graded [0] correct
            assert bool(np.isin(gold_post, row))

    def test_streaming_and_batched_rounds_agree_with_stop(self):
        """SwarmExecutor streaming vs batched with a mid-sequence stop
        token: identical truncated answers, identical winners, and u
        computed over the SAME answer span (streaming retires at the stop
        token; batched masks its Eq. 2-4 terms to match)."""
        e1, e2 = _engine(ARCHS["attn"]), _engine(ARCHS["ssd"])
        prompts = pad_prompts(CTX)
        stop = int(e1.generate(prompts, 6)["tokens"][0, 2])
        batched = SwarmExecutor([e1, e2], stop_token=stop).collaborate(
            prompts, 6)
        streamed = SwarmExecutor([e1, e2], stop_token=stop, streaming=True,
                                 serve_slots=2).collaborate(prompts, 6)
        np.testing.assert_array_equal(batched["answers"],
                                      streamed["answers"])
        np.testing.assert_array_equal(batched["winner_member"],
                                      streamed["winner_member"])
        np.testing.assert_allclose(batched["u"], streamed["u"], atol=1e-5)

    def test_streaming_stop_token_saves_decode_steps(self):
        """The streaming round passes its stop token through to serve():
        requests retire early instead of decoding to max_new."""
        eng = _engine(ARCHS["attn"])
        prompts = pad_prompts(CTX)
        base = eng.generate(prompts, 6)["tokens"]
        stop = int(base[0, 2])

        seen = []
        orig = eng.serve

        def spy(*a, **kw):
            seen.append(kw.get("stop_token"))
            return orig(*a, **kw)

        eng.serve = spy
        try:
            SwarmExecutor([eng], stop_token=stop, streaming=True,
                          serve_slots=2).collaborate(prompts, 6)
        finally:
            eng.serve = orig
        assert seen == [stop]


class TestSwarmStateReuse:
    def test_precomputed_member_issues_zero_dispatches(self):
        """A member whose answer is precomputed (the gateway's probe) must
        not prefill, continue, or decode during the round."""
        probe, peer = _engine(ARCHS["attn"]), _engine(ARCHS["ssd"])
        prompts = pad_prompts(CTX)
        res = probe.generate(prompts, 6, return_state=True)
        before = dict(probe.counters)
        sw = SwarmExecutor([probe, peer]).collaborate(
            prompts, 6,
            precomputed={0: (res["tokens"], res["u"],
                             (res["h_mean"], res["v_mean"]))},
            states={0: res["state"]})
        assert probe.counters == before
        assert peer.counters["prefill"] >= 1    # the peer really ran
        np.testing.assert_array_equal(sw["answers"][:, 0], res["tokens"])

    def test_escalation_deepening_extends_from_state(self):
        """When the round wants a longer answer than the probe produced,
        the probe member extends decode-only from its warm cache — zero
        prefills — and the extended answer is bitwise what a longer
        original generation would have been."""
        probe, peer = _engine(ARCHS["attn"]), _engine(ARCHS["ssd"])
        prompts = pad_prompts(CTX)
        res = probe.generate(prompts, 4, return_state=True, seed=3)
        before = dict(probe.counters)
        sw = SwarmExecutor([probe, peer]).collaborate(
            prompts, 8, seed=3,
            precomputed={0: (res["tokens"], res["u"],
                             (res["h_mean"], res["v_mean"]))},
            states={0: res["state"]})
        assert probe.counters["prefill"] == before["prefill"]
        assert probe.counters["prefill_continue"] == \
            before["prefill_continue"]
        assert probe.counters["decode_only"] == before["decode_only"] + 1
        long = probe.generate(prompts, 8, seed=3)
        np.testing.assert_array_equal(sw["answers"][:, 0], long["tokens"])
        np.testing.assert_allclose(sw["u"][:, 0], long["u"], atol=1e-5)
