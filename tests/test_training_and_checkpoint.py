"""Training-substrate tests: optimisation progress, grad-accumulation
equivalence, checkpoint atomicity + restore, LoRA distillation."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.data.pipeline import SyntheticLMPipeline
from repro.data.workload import FactWorld
from repro.models import lora as lora_lib
from repro.models import transformer as T
from repro.training import checkpoint as ck
from repro.training import optimizer as opt
from repro.training import train as TR


@pytest.fixture()
def tiny():
    import dataclasses
    # vocab 512 so the FactWorld token layout is in-range
    cfg = dataclasses.replace(C.get_smoke("smollm-135m"), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_loss_decreases(tiny):
    cfg, params = tiny
    step = TR.build_train_step(cfg, opt.AdamWConfig(lr=5e-3, total_steps=40),
                               None)
    state = opt.init(params)
    pipe = SyntheticLMPipeline(8, 64, world=FactWorld(n_ent=8, n_rel=4))
    losses = []
    for s in range(40):
        b = {k: jnp.asarray(v) for k, v in pipe.get_batch(s).items()}
        params, state, m = step(params, state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::8]


def test_grad_accumulation_equivalence(tiny):
    cfg, params = tiny
    ocfg = opt.AdamWConfig(lr=1e-3)
    pipe = SyntheticLMPipeline(8, 32)
    batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(0).items()}
    s1 = TR.build_train_step(cfg, ocfg, None, microbatches=1, donate=False)
    s2 = TR.build_train_step(cfg, ocfg, None, microbatches=2, donate=False)
    p1, _, m1 = s1(params, opt.init(params), batch)
    p2, _, m2 = s2(params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=5e-2)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p1, p2)
    assert max(jax.tree.leaves(diffs)) < 5e-2


def test_checkpoint_roundtrip(tmp_path, tiny):
    cfg, params = tiny
    state = opt.init(params)
    tree = {"params": params, "opt": state}
    path = ck.save(str(tmp_path), 7, tree, extra={"step": 7})
    assert os.path.exists(os.path.join(path, "manifest.json"))
    assert ck.latest_step(str(tmp_path)) == 7

    abs_tree = {"params": T.abstract_params(cfg),
                "opt": opt.abstract_state(T.abstract_params(cfg))}
    restored, extra = ck.restore(str(tmp_path), 7, abs_tree)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_prune_and_latest(tmp_path, tiny):
    cfg, params = tiny
    for s in (1, 2, 3, 4):
        ck.save(str(tmp_path), s, {"p": params["final_norm"]}, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_3", "step_4"]
    assert ck.latest_step(str(tmp_path)) == 4


def test_restart_replays_data_stream():
    pipe = SyntheticLMPipeline(4, 32, seed=3)
    b5 = pipe.get_batch(5)
    pipe2 = SyntheticLMPipeline(4, 32, seed=3)     # "restarted process"
    np.testing.assert_array_equal(b5["tokens"], pipe2.get_batch(5)["tokens"])


def test_lora_distillation_moves_student(tiny):
    from repro.core.distill import distill_step
    cfg, params = tiny
    lora = lora_lib.init_lora(params, jax.random.PRNGKey(1), rank=4)
    batch = {
        "tokens": jnp.zeros((2, 8), jnp.int32),
        "labels": jnp.ones((2, 8), jnp.int32),
        "loss_mask": jnp.ones((2, 8), jnp.float32),
    }
    teacher = jax.random.normal(jax.random.PRNGKey(2),
                                (2, 8, cfg.vocab_size))
    l0 = None
    for _ in range(5):
        lora, loss = distill_step(lora, params, cfg, batch, teacher, lr=1e-2)
        l0 = l0 or float(loss)
    assert float(loss) < l0
    # base params untouched; adapters changed
    b_leaves = jax.tree.leaves(lora)
    assert any(float(jnp.abs(x).max()) > 0 for x in b_leaves)


def test_optimizer_state_abstract_matches_init(tiny):
    cfg, params = tiny
    st = opt.init(params)
    ab = opt.abstract_state(T.abstract_params(cfg))
    real_flat = jax.tree.leaves(st)
    abs_flat = jax.tree.leaves(ab)
    assert len(real_flat) == len(abs_flat)
    for r, a in zip(real_flat, abs_flat):
        assert r.shape == a.shape and r.dtype == a.dtype
