"""Unit tests for the paper's equations (Sec. IV), against hand calculations."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import budget as B
from repro.core import consensus as CO
from repro.core import cost_model as CM
from repro.core import privacy as PV
from repro.core import router as R
from repro.core import uncertainty as U


class TestUncertainty:
    def test_token_nent_hand(self):
        # two tokens, p(t) = [1, 0.5] -> -p log p = [0, 0.5*log2]
        logits = jnp.array([[[100.0, 0.0, 0.0], [1.0, 1.0, -1e9]]])
        toks = jnp.array([[0, 0]])
        per = U.token_nent(logits, toks)
        np.testing.assert_allclose(per[0, 0], 0.0, atol=1e-5)
        np.testing.assert_allclose(per[0, 1], 0.5 * np.log(2), rtol=1e-5)

    def test_eq2_mean_over_positions(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 16))
        toks = jnp.zeros((2, 5), jnp.int32)
        h = U.sequence_entropy(logits, toks)
        np.testing.assert_allclose(h, U.token_nent(logits, toks).mean(-1),
                                   rtol=1e-6)

    def test_eq3_topk_variance_hand(self):
        logits = jnp.array([[[4.0, 2.0, 0.0, -50.0]]])
        v = U.topk_logit_variance(logits, k=3)  # var([4,2,0]) = 8/3
        np.testing.assert_allclose(v[0, 0], 8.0 / 3, rtol=1e-6)

    def test_eq4_mixture_bounds(self):
        logits = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64)) * 5
        toks = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0, 64)
        for alpha in (0.0, 0.5, 1.0):
            u = U.difficulty(logits, toks, U.UncertaintyConfig(alpha=alpha))
            assert u.shape == (4,)
            assert (u >= 0).all() and (u <= 1.0 + 1e-6).all()

    def test_flat_vs_confident_distribution_mode(self):
        V = 64
        conf = jnp.zeros((1, 4, V)).at[..., 3].set(25.0)
        flat = jnp.zeros((1, 4, V))
        toks = jnp.full((1, 4), 3, jnp.int32)
        cfg = U.UncertaintyConfig(alpha=1.0, mode="distribution")
        assert float(U.difficulty(flat, toks, cfg)[0]) > \
            float(U.difficulty(conf, toks, cfg)[0])

    def test_invert_variance_flag(self):
        logits = jnp.zeros((1, 4, 64)).at[..., 0].set(30.0)
        toks = jnp.zeros((1, 4), jnp.int32)
        base = U.UncertaintyConfig(alpha=0.0)
        inv = U.UncertaintyConfig(alpha=0.0, invert_variance=True)
        u0 = float(U.difficulty(logits, toks, base)[0])
        u1 = float(U.difficulty(logits, toks, inv)[0])
        np.testing.assert_allclose(u0 + u1, 1.0, atol=1e-5)


class TestConsensus:
    def test_eq14_hand(self):
        # nodes 0,1 agree; weights w = clip(1-U, 0.05, 1)
        ans = jnp.array([[7, 8, -1], [7, 8, -1], [9, -1, -1]])
        u = jnp.array([0.2, 0.4, 0.1])
        res = CO.weighted_consensus(ans, u)
        w = np.clip(1 - np.array([0.2, 0.4, 0.1]), 0.05, 1)
        np.testing.assert_allclose(float(res.best_score),
                                   (w[0] + w[1]) / w.sum(), rtol=1e-6)
        assert int(res.rep_index) in (0, 1)

    def test_w_min_floor(self):
        ans = jnp.array([[1, -1], [2, -1]])
        u = jnp.array([1.0, 0.0])  # node 0 fully uncertain
        res = CO.weighted_consensus(ans, u)
        np.testing.assert_allclose(float(res.weights[0]), 0.05)

    def test_longest_representative(self):
        ans = jnp.array([[5, 6, -1, -1], [5, 6, 7, 8], [5, 6, -1, -1]])
        # make all one cluster? they're different sequences -> distinct
        u = jnp.array([0.1, 0.95, 0.1])
        res = CO.weighted_consensus(ans, u)
        # cluster {0,2} wins; rep is one of them (equal lengths)
        assert int(res.rep_index) in (0, 2)

    def test_gamma_gate(self):
        res = CO.weighted_consensus(jnp.array([[1], [2], [3]]),
                                    jnp.array([0.5, 0.5, 0.5]))
        assert int(CO.consensus_decision(res, gamma=0.6)) == 0
        assert int(CO.consensus_decision(res, gamma=0.3)) == 1


class TestRouterAlg1:
    def _route(self, u, s, total=1.0, wan=True, cost=0.001):
        n = len(u)
        return R.route(jnp.array(u), jnp.array(s),
                       cfg=R.RouterConfig.final(),
                       budget=B.init_budget(total), wan_ok=wan,
                       est_cloud_cost=jnp.full((n,), cost))

    def test_levels(self):
        r = self._route([0.01, 0.15, 0.9], [0.0, 0.0, 0.0])
        assert r.decision.tolist() == [R.LOCAL, R.SWARM, R.CLOUD]

    def test_risk_forces_cloud(self):
        r = self._route([0.01], [0.99])
        assert r.decision.tolist() == [R.CLOUD_SAFETY]

    def test_risk_without_wan_refuses(self):
        r = self._route([0.01], [0.99], wan=False)
        assert r.decision.tolist() == [R.REFUSE]

    def test_budget_exhaustion_falls_back_to_swarm(self):
        r = self._route([0.9, 0.9], [0.0, 0.0], total=0.0015, cost=0.001)
        assert r.decision.tolist() == [R.CLOUD, R.SWARM]

    def test_post_consensus_escalation(self):
        r = self._route([0.15, 0.15], [0.0, 0.0])
        pc = R.post_consensus(r.decision, jnp.array([0.9, 0.1]),
                              cfg=R.RouterConfig.final(), budget=r.budget,
                              wan_ok=True,
                              est_cloud_cost=jnp.full((2,), 0.001))
        assert pc.decision.tolist() == [R.SWARM, R.CLOUD]
        assert pc.use_swarm_answer.tolist() == [True, False]


class TestBudgetEq13:
    def test_sequential_semantics(self):
        adm, st = B.charge_batch(B.init_budget(0.025),
                                 jnp.full((4,), 0.01),
                                 jnp.array([True, True, True, True]))
        assert adm.tolist() == [True, True, False, False]
        np.testing.assert_allclose(float(st.used), 0.02)

    def test_window_roll(self):
        st = B.init_budget(1.0)._replace(used=jnp.float32(0.9))
        st2 = B.roll_window(st, jnp.int32(1))
        assert float(st2.used) == 0.0


class TestCostEq7to9:
    def test_eq7(self):
        p = CM.CostParams()
        c = CM.cost_cloud(jnp.float32(100), jnp.float32(50), p)
        np.testing.assert_allclose(float(c), 150 * 0.88e-6, rtol=1e-6)

    def test_eq9_max_and_quorum(self):
        p = CM.LatencyParams(agg_overhead=0.0)
        edge = jnp.array([[1.0, 2.0, 5.0]])
        comm = jnp.zeros((1, 3))
        full = CM.latency_swarm(edge, comm, p)
        q2 = CM.latency_swarm(edge, comm, p, quorum=2)
        assert float(full[0]) == 5.0 and float(q2[0]) == 2.0


class TestPrivacyEq15to17:
    def test_hand_computed(self):
        dec = jnp.array([R.LOCAL, R.CLOUD, R.SWARM, R.CLOUD_SAFETY])
        plen = jnp.array([10, 30, 10, 50])
        saf = jnp.array([False, False, False, True])
        m = PV.privacy_metrics(dec, plen, saf)
        np.testing.assert_allclose(float(m.cer), 0.5)
        np.testing.assert_allclose(float(m.ter), 80 / 100)
        np.testing.assert_allclose(float(m.ser), 1.0)
