"""Unit tests for serving-layer components: engine, swarm, simulator,
workload, meshes, and the dry-run collective parser."""

import dataclasses

import jax
import numpy as np
import pytest

from repro import configs as C
from repro.core.cost_model import LatencyParams
from repro.core.uncertainty import UncertaintyConfig
from repro.data.workload import FACT_IS, FactWorld, is_correct
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.simulator import NetworkSimulator, SimConfig
from repro.serving.swarm import SwarmExecutor, pad_prompts, truncate_at_stop


@pytest.fixture(scope="module")
def tiny_engine():
    cfg = dataclasses.replace(C.get_smoke("smollm-135m"), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return InferenceEngine("t", cfg, params,
                           UncertaintyConfig(mode="distribution"))


class TestEngine:
    def test_generate_shapes(self, tiny_engine):
        prompts = pad_prompts([[3, 20, 195, 2], [3, 21, 196, 2]])
        res = tiny_engine.generate(prompts, 4)
        assert res["tokens"].shape == (2, 4)
        assert res["u"].shape == (2,)
        assert (res["u"] >= 0).all() and (res["u"] <= 1).all()

    def test_greedy_is_deterministic(self, tiny_engine):
        prompts = pad_prompts([[3, 20, 195, 2]])
        a = tiny_engine.generate(prompts, 4, seed=0)
        b = tiny_engine.generate(prompts, 4, seed=7)  # greedy ignores seed
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


class TestSwarm:
    def test_pad_prompts_alignment(self):
        left = pad_prompts([[1, 2], [3, 4, 5]])
        assert left.tolist() == [[0, 1, 2], [3, 4, 5]]
        right = pad_prompts([[1, 2], [3, 4, 5]], align="right")
        assert right.tolist() == [[1, 2, 0], [3, 4, 5]]

    def test_truncate_at_stop(self):
        from repro.core.consensus import PAD  # consensus pad = -1
        t = np.array([[7, FACT_IS, 9, 9], [7, 8, 9, FACT_IS]])
        out = truncate_at_stop(t, FACT_IS)
        assert out.tolist() == [[7, PAD, PAD, PAD], [7, 8, 9, PAD]]

    def test_collaborate_with_failed_member(self, tiny_engine):
        sw = SwarmExecutor([tiny_engine, tiny_engine, tiny_engine],
                           stop_token=FACT_IS)
        prompts = pad_prompts([[3, 20, 195, 2]])
        res = sw.collaborate(prompts, 4,
                             member_mask=np.array([True, True, False]))
        # identical engines agree -> the two live members cluster together
        assert res["consensus_score"][0] > 0.5
        assert res["winner_tokens"].shape == (1, 4)


class TestSimulator:
    def test_wan_outage_recovery_cycle(self):
        sim = NetworkSimulator(SimConfig(wan_outage_p=1.0, wan_recover_p=1.0),
                               LatencyParams(), 3)
        sim.tick()
        assert not sim.wan_up
        sim.tick()
        assert sim.wan_up

    def test_latency_positive_and_scales(self):
        sim = NetworkSimulator(SimConfig(seed=1), LatencyParams(), 3)
        le = sim.edge_latency(np.array([10, 100]))
        assert (le > 0).all() and le[1] > le[0]
        lc = sim.cloud_latency(np.array([10, 10, 10, 10]))
        assert (lc > 0).all()

    def test_straggler_injection(self):
        sim = NetworkSimulator(SimConfig(straggler_p=1.0, straggler_mult=10),
                               LatencyParams(), 3)
        base = NetworkSimulator(SimConfig(straggler_p=0.0),
                                LatencyParams(), 3)
        assert sim.peer_comm(50, 3).mean() > 3 * base.peer_comm(50, 3).mean()

    def test_mttr_is_geometric_mean_sojourn(self):
        """Recovery is per-tick Bernoulli, so downtime is geometric with
        mean 1/recover_p ticks — the empirical MTTR of a seeded run must
        match SimConfig.mean_ticks_to_recover."""
        cfg = SimConfig(seed=3, node_fail_p=0.0, node_recover_p=0.25,
                        wan_outage_p=0.0)
        assert cfg.mean_ticks_to_recover("node") == 4.0
        assert cfg.mean_ticks_to_recover("wan") == 2.0
        assert SimConfig(node_recover_p=0.0).mean_ticks_to_recover("node") \
            == float("inf")
        sim = NetworkSimulator(cfg, LatencyParams(), 1)
        durations = []
        for _ in range(400):
            sim.member_up[0] = False         # force an outage, time recovery
            ticks = 0
            while not sim.member_up[0]:
                sim.tick()
                ticks += 1
            durations.append(ticks)
        assert np.mean(durations) == pytest.approx(4.0, abs=0.5)

    def test_reset_rewinds_seeded_state(self):
        sim = NetworkSimulator(SimConfig(seed=5, node_fail_p=0.3),
                               LatencyParams(), 4)
        for _ in range(6):
            sim.tick()
        trace_a = (sim.wan_up, sim.member_up.copy(), sim.wan_rtt(3).copy())
        sim.reset()
        assert sim.wan_up and sim.member_up.all()
        for _ in range(6):
            sim.tick()
        trace_b = (sim.wan_up, sim.member_up.copy(), sim.wan_rtt(3).copy())
        assert trace_a[0] == trace_b[0]
        np.testing.assert_array_equal(trace_a[1], trace_b[1])
        np.testing.assert_array_equal(trace_a[2], trace_b[2])


class TestWorkload:
    def test_study_composition(self):
        w = FactWorld(n_ent=16, n_rel=6)
        qs = w.study_workload()
        cats = [q["category"] for q in qs]
        assert cats.count("easy") == 20
        assert cats.count("hard") == 20
        assert cats.count("safety") == 10

    def test_gold_answers_consistent(self):
        w = FactWorld(n_ent=16, n_rel=6)
        for q in w.easy_queries(8):
            e, r = q["prompt"][1] - 16, q["prompt"][2] - 192
            assert q["gold"] == w.answer_1hop(e, r)

    def test_is_correct_substring_semantics(self):
        assert is_correct([5, 301, 9], 301)
        assert not is_correct([5, 300, 9], 301)
        assert not is_correct([301], None)

    def test_training_batch_deterministic(self):
        w = FactWorld(n_ent=8, n_rel=4)
        a = w.training_batch(4, 32, step=9, two_hop=True)
        b = w.training_batch(4, 32, step=9, two_hop=True)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


class TestCollectiveParser:
    def test_parses_shapes_and_groups(self):
        import os
        prev = os.environ.get("XLA_FLAGS")
        from repro.launch import dryrun  # import sets XLA_FLAGS...
        # ...restore so later subprocess-spawning tests see a clean env
        if prev is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = prev
        hlo = """
  %all-gather.1 = f32[16,1024]{1,0} all-gather(%x), replica_groups=[4,2]<=[8]
  %all-reduce.2 = bf16[256]{0} all-reduce(%y), replica_groups=[2,4]<=[8]
  %collective-permute.3 = f32[8,8]{1,0} collective-permute(%z), source_target_pairs={{0,1},{1,0}}
"""
        out = dryrun.parse_collectives(hlo)
        assert out["all-gather"] == (2 - 1) / 2 * 16 * 1024 * 4
        assert out["all-reduce"] == 2 * (4 - 1) / 4 * 256 * 2
        assert out["collective-permute"] == 8 * 8 * 4
        assert out["counts"]["all-gather"] == 1


class TestMesh:
    def test_elastic_mesh_single_device(self):
        from repro.launch.mesh import data_shards, elastic_mesh
        m = elastic_mesh()
        assert data_shards(m) >= 1
        assert "model" in m.shape
