"""Retrace-budget regression (ISSUE 9 satellite).

The engine buckets prompt shapes so repeated traffic re-uses compiled
programs; PR 6's compilation-cache test proves this across *processes*
via the cache file set.  This test proves it in-process: after one
``generate()`` warmed a shape bucket, a second ``generate()`` on the
same bucket (different content, different exact S inside the bucket)
must perform ZERO fresh traces on any jit entry point in the engine
module — counted directly off the jitted functions' trace caches.
"""

import dataclasses

import jax
import numpy as np

from repro import configs as C
from repro.core.uncertainty import UncertaintyConfig
from repro.models import transformer as T
from repro.serving import engine as E
from repro.serving.engine import InferenceEngine


def _trace_counts():
    """Trace-cache sizes of every module-level jit in serving.engine."""
    counts = {}
    for name, obj in vars(E).items():
        size = getattr(obj, "_cache_size", None)
        if callable(size):
            counts[name] = size()
    return counts


def _engine(**kw):
    cfg = dataclasses.replace(C.get_smoke("smollm-135m"), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return InferenceEngine("rt", cfg, params, UncertaintyConfig(), **kw)


def _prompts(seed, s):
    return np.random.RandomState(seed).randint(
        7, 500, size=(2, s)).astype(np.int32)


class TestRetraceBudget:
    def test_second_generate_same_bucket_traces_nothing(self):
        eng = _engine()
        eng.generate(_prompts(0, 30), 4)
        warm = _trace_counts()
        # same bucket (30 and 31 both round to the 32 bucket), new content
        eng.generate(_prompts(1, 31), 4)
        eng.generate(_prompts(2, 30), 4)
        after = _trace_counts()
        grew = {k: (warm[k], after[k]) for k in warm if after[k] > warm[k]}
        assert not grew, f"fresh traces on a warm bucket: {grew}"

    def test_new_bucket_traces_then_stabilises(self):
        eng = _engine()
        eng.generate(_prompts(0, 30), 4)
        warm = _trace_counts()
        eng.generate(_prompts(1, 60), 4)        # 64 bucket: traces expected
        mid = _trace_counts()
        assert sum(mid.values()) > sum(warm.values())
        eng.generate(_prompts(2, 57), 4)        # same 64 bucket: none
        after = _trace_counts()
        assert after == mid

    def test_paged_engine_same_budget(self):
        eng = _engine(paged=True, block_len=16)
        eng.generate(_prompts(0, 30), 4)
        warm = _trace_counts()
        eng.generate(_prompts(1, 31), 4)
        after = _trace_counts()
        grew = {k: (warm[k], after[k]) for k in warm if after[k] > warm[k]}
        assert not grew, f"fresh paged traces on a warm bucket: {grew}"

    def test_stepwise_absorb_uses_no_key(self):
        """The absorb loop passes rng=None (greedy): S absorb steps must
        not consume or alias the decode stream's key — sampled stepwise
        decode draws from exactly the post-absorb split sequence."""
        eng = _engine()
        p = _prompts(0, 12)
        r1 = eng.generate_stepwise(p, 4, greedy=False, seed=3)
        r2 = eng.generate_stepwise(p, 4, greedy=False, seed=3)
        assert np.array_equal(r1["tokens"], r2["tokens"])
        r3 = eng.generate_stepwise(p, 4, greedy=False, seed=4)
        assert not np.array_equal(r1["tokens"], r3["tokens"]) or \
            r1["tokens"].size == 0
