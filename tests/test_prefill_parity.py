"""Prefill/decode parity + streaming-serve tests for the two-phase runtime.

The jitted prefill + scanned decode path must reproduce the legacy stepwise
absorption loop: bitwise-identical greedy tokens and matching difficulty
scores u, for all three mixer kinds (attn, rglru+attn_local, ssd).  Bucketed
prompt padding (inert negative positions) must be bitwise-neutral, and the
mesh-sharded runtime (docs/SHARDING.md) must reproduce the single-device
greedy stream — on the degenerate (1, 1) mesh bit-for-bit in-process, and
on a real (data=4, model=2) mesh via an 8-fake-device subprocess (which
also covers a MoE config; the MoE serving-dispatch semantics themselves
live in tests/test_moe_serving.py).
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.core.uncertainty import UncertaintyConfig
from repro.models import transformer as T
from repro.serving import engine as E
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import ContinuousBatcher, Request
from repro.serving.swarm import pad_prompts

MIXER_ARCHS = {
    "attn": "smollm-135m",
    "rglru": "recurrentgemma-2b",
    "ssd": "mamba2-780m",
}


def _engine(arch: str) -> InferenceEngine:
    cfg = dataclasses.replace(C.get_smoke(arch), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return InferenceEngine(arch, cfg, params,
                           UncertaintyConfig(mode="distribution"))


@pytest.fixture(scope="module", params=sorted(MIXER_ARCHS))
def engine(request):
    return _engine(MIXER_ARCHS[request.param])


# ragged lengths so the bucketed prefill also covers original PAD columns
PROMPTS = [[3, 20, 195, 2], [3, 21, 196, 199, 2], [7, 9, 2]]


class TestPrefillDecodeParity:
    def test_tokens_and_u_match_stepwise(self, engine):
        prompts = pad_prompts(PROMPTS)
        new = engine.generate(prompts, 6)
        old = engine.generate_stepwise(prompts, 6)
        np.testing.assert_array_equal(new["tokens"], old["tokens"])
        # u differs only by bf16 activation noise between the parallel and
        # sequential absorption orders
        np.testing.assert_allclose(new["u"], old["u"], atol=1e-4)

    def test_bucket_padding_is_bitwise_neutral(self, engine):
        """Extra bucket columns (negative positions) must not change any
        generated logit: compare against a manual unbucketed invocation."""
        prompts = pad_prompts(PROMPTS)     # S=5 -> bucket 8 inside generate
        B, S = prompts.shape
        res = engine.generate(prompts, 6)
        toks, lgs = E._generate_fused(
            engine.params, engine.cfg, jnp.asarray(prompts), jnp.int32(S),
            jax.random.PRNGKey(0), engine.ucfg, 6,
            engine._cache_len(E.bucket_len(S), 6), True)[:2]
        np.testing.assert_array_equal(res["tokens"], np.asarray(toks))
        np.testing.assert_array_equal(np.asarray(res["logits"]),
                                      np.asarray(lgs))

    def test_prefill_cache_matches_stepwise_decode(self, engine):
        """After prefill, continuing with decode_step must agree with the
        stepwise loop's first continuation token."""
        prompts = pad_prompts(PROMPTS)
        new = engine.generate(prompts, 1)
        old = engine.generate_stepwise(prompts, 1)
        np.testing.assert_array_equal(new["tokens"], old["tokens"])


class TestStreamingServe:
    def test_serve_matches_generate(self, engine):
        prompts = pad_prompts(PROMPTS)
        res = engine.generate(prompts, 6)
        reqs = [Request(rid=i, prompt=prompts[i].tolist(), max_new=6)
                for i in range(len(PROMPTS))]
        fin = engine.serve(reqs, n_slots=2, decode_chunk=4)
        assert len(fin) == len(PROMPTS)
        for r in fin:
            np.testing.assert_array_equal(r["tokens"], res["tokens"][r["rid"]])
            np.testing.assert_allclose(r["u"], res["u"][r["rid"]], atol=1e-5)

    def test_midflight_admission_and_stop_token(self):
        """More requests than slots -> admission happens mid-flight; a stop
        token retires a request before max_new."""
        eng = _engine(MIXER_ARCHS["attn"])
        prompts = pad_prompts(PROMPTS)
        base = eng.generate(prompts, 6)
        stop = int(base["tokens"][0, 2])    # force an early retire for rid 0
        reqs = [Request(rid=k, prompt=prompts[k % len(PROMPTS)].tolist(),
                        max_new=6) for k in range(6)]   # 6 requests, 2 slots
        batcher = ContinuousBatcher(2)
        for r in reqs:
            batcher.submit(r)
        fin = eng.serve(batcher=batcher, decode_chunk=3, stop_token=stop)
        assert len(fin) == 6 and batcher.idle
        by_rid = {r["rid"]: r for r in fin}
        # every request retired at its first stop-token occurrence (or ran
        # to max_new), with the same greedy stream as batched generate
        assert any(len(r["tokens"]) < 6 for r in fin)
        for k, r in by_rid.items():
            row = base["tokens"][k % len(PROMPTS)]
            hits = np.where(row == stop)[0]
            n = int(hits[0]) + 1 if len(hits) else 6
            assert len(r["tokens"]) == n
            np.testing.assert_array_equal(r["tokens"], row[:n])

    def test_serve_empty_is_noop(self):
        eng = _engine(MIXER_ARCHS["attn"])
        assert eng.serve([]) == []

    def test_serve_rejects_preadmitted_batcher(self):
        eng = _engine(MIXER_ARCHS["attn"])
        batcher = ContinuousBatcher(2)
        batcher.submit(Request(rid=0, prompt=[3, 20, 2], max_new=2))
        batcher.admit()
        with pytest.raises(ValueError, match="un-admitted"):
            eng.serve(batcher=batcher)

    def test_sharded_runtime_on_degenerate_mesh_is_bitwise_identical(self):
        """The mesh-sharded engine on the (1, 1) serving mesh must be
        bit-for-bit the unsharded engine — generate (tokens AND logits) and
        the streaming serve path.  Keeps the sharded code path exercised in
        single-device CI; real multi-device parity runs in the subprocess
        test below."""
        from repro.launch.mesh import serving_mesh
        for arch in MIXER_ARCHS.values():
            base = _engine(arch)
            shard = InferenceEngine(arch, base.cfg, base.params, base.ucfg,
                                    mesh=serving_mesh())
            prompts = pad_prompts(PROMPTS)
            r0 = base.generate(prompts, 6)
            r1 = shard.generate(prompts, 6)
            np.testing.assert_array_equal(r0["tokens"], r1["tokens"])
            np.testing.assert_array_equal(np.asarray(r0["logits"]),
                                          np.asarray(r1["logits"]))
            fin = shard.serve([Request(rid=i, prompt=prompts[i].tolist(),
                                       max_new=6)
                               for i in range(len(PROMPTS))], n_slots=2)
            for r in fin:
                np.testing.assert_array_equal(r["tokens"],
                                              r0["tokens"][r["rid"]])

    def test_swarm_streaming_matches_batched(self):
        """A swarm round through the streaming serve path clusters the same
        answers as the batched per-member invocation."""
        from repro.serving.swarm import SwarmExecutor
        eng = _engine(MIXER_ARCHS["attn"])
        prompts = pad_prompts(PROMPTS)
        batched = SwarmExecutor([eng, eng]).collaborate(prompts, 4)
        streamed = SwarmExecutor([eng, eng], streaming=True,
                                 serve_slots=2).collaborate(prompts, 4)
        np.testing.assert_array_equal(batched["answers"],
                                      streamed["answers"])
        np.testing.assert_allclose(batched["u"], streamed["u"], atol=1e-5)


# ---------------------------------------------------------------------------
# Multi-device sharded parity (subprocess: the XLA host-device-count flag
# must be set before jax initialises and must not leak into other tests)
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, numpy as np
from repro import configs as C
from repro.core.uncertainty import UncertaintyConfig
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import Request
from repro.serving.swarm import pad_prompts
from repro.launch.mesh import serving_mesh

PROMPTS = [[3, 20, 195, 2], [3, 21, 196, 199, 2], [7, 9, 2], [5, 6, 7, 2]]
mesh = serving_mesh(model_parallel=2)
assert dict(mesh.shape) == {"data": 4, "model": 2}, mesh.shape
for arch in ("smollm-135m", "recurrentgemma-2b", "mamba2-780m",
             "deepseek-moe-16b"):
    cfg = dataclasses.replace(C.get_smoke(arch), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ucfg = UncertaintyConfig(mode="distribution")
    base = InferenceEngine(arch, cfg, params, ucfg)
    shard = InferenceEngine(arch, cfg, params, ucfg, mesh=mesh)
    prompts = pad_prompts(PROMPTS)
    r0 = base.generate(prompts, 6)
    r1 = shard.generate(prompts, 6)
    np.testing.assert_array_equal(r0["tokens"], r1["tokens"])
    np.testing.assert_allclose(r0["u"], r1["u"], atol=1e-4)
    # continuation prefill over a live cache: single-device warm == cold
    # prefill of the concatenation BITWISE; the (4,2)-sharded warm path
    # partitions the cache-wide attention reductions differently, so its
    # logits carry ~1 bf16 ulp vs single-device (same noise class the cold
    # test absorbs via argmax margins) — compared tie-aware: greedy streams
    # must agree except where the top-2 margin is inside that noise, and
    # only the prefix before a tie flip is comparable (histories diverge).
    # Mirrors tests/test_continuation._assert_greedy_match_modulo_ties
    # (this subprocess can't import the tests package; keep them in sync).
    span = pad_prompts([[11, 12, 2], [13, 2], [14, 15, 16, 2], [17, 2]])
    w0 = base.generate(span, 6, state=base.absorb(prompts))
    w1 = shard.generate(span, 6, state=shard.absorb(prompts))
    cold = base.generate(np.concatenate([prompts, span], axis=1), 6)
    np.testing.assert_array_equal(w0["tokens"], cold["tokens"])
    np.testing.assert_array_equal(np.asarray(w0["logits"]),
                                  np.asarray(cold["logits"]))
    l0, l1 = np.asarray(w0["logits"]), np.asarray(w1["logits"])
    for b in range(w0["tokens"].shape[0]):
        mism = np.where(w0["tokens"][b] != w1["tokens"][b])[0]
        n = mism[0] if len(mism) else w0["tokens"].shape[1]
        np.testing.assert_array_equal(w0["tokens"][b, :n],
                                      w1["tokens"][b, :n])
        np.testing.assert_allclose(l0[b, :n], l1[b, :n], atol=0.01, rtol=0)
        if len(mism):
            top2 = np.sort(l0[b, mism[0]])[-2:]
            assert top2[1] - top2[0] <= 0.02, (arch, b, mism[0], top2)
    if arch == "smollm-135m":
        # B=2 slots over data=4: the replicated-batch layout that used to
        # crash XLA CPU's grouped-conv partitioner (see ssm._causal_conv_step)
        fin = shard.serve([Request(rid=i, prompt=prompts[i].tolist(),
                                   max_new=6) for i in range(len(PROMPTS))],
                          n_slots=2, decode_chunk=3)
        assert len(fin) == len(PROMPTS)
        for r in fin:
            np.testing.assert_array_equal(r["tokens"], r0["tokens"][r["rid"]])
    print(arch, "ok", flush=True)
print("RESULT ok")
"""


def test_sharded_generate_matches_single_device():
    """Mesh-sharded generate/serve on a real (data=4, model=2) mesh emits
    the same greedy tokens as the single-device engine, for all three
    mixer kinds plus a MoE ffn (masked serving dispatch under SPMD)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RESULT ok" in proc.stdout, proc.stdout
