"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.swarm_uncertainty.kernel import uncertainty_pallas
from repro.kernels.swarm_uncertainty.ref import uncertainty_ref

KEYS = jax.random.split(jax.random.PRNGKey(0), 8)


class TestSwarmUncertainty:
    @pytest.mark.parametrize("B,N,V,bn,bv,k", [
        (2, 16, 4096, 8, 1024, 10),
        (1, 8, 512, 8, 128, 5),
        (3, 32, 8192, 8, 2048, 16),
        (1, 8, 1024, 4, 256, 1),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, N, V, bn, bv, k, dtype):
        logits = (jax.random.normal(KEYS[0], (B, N, V), jnp.float32) * 3
                  ).astype(dtype)
        toks = jax.random.randint(KEYS[1], (B, N), 0, V)
        h, v, hd = uncertainty_pallas(logits, toks, k=k, bn=bn, bv=bv,
                                      interpret=True)
        hr, vr, hdr = uncertainty_ref(logits, toks, k=k)
        tol = 1e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(h, hr, rtol=tol, atol=tol)
        np.testing.assert_allclose(v, vr, rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(hd, hdr, rtol=tol, atol=tol)

    def test_extreme_logits_stable(self):
        logits = jnp.full((1, 8, 512), -1e4).at[..., 0].set(1e4)
        toks = jnp.zeros((1, 8), jnp.int32)
        h, v, hd = uncertainty_pallas(logits, toks, k=4, bv=128,
                                      interpret=True)
        assert np.isfinite(np.asarray(h)).all()
        assert np.isfinite(np.asarray(v)).all()


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,K,D,causal,window,bq,bk", [
        (2, 256, 4, 2, 64, True, None, 64, 64),
        (1, 128, 8, 8, 32, False, None, 64, 32),
        (2, 256, 6, 2, 64, True, 64, 64, 64),      # sliding window
        (1, 512, 4, 1, 128, True, None, 128, 128),  # MQA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, S, H, K, D, causal, window, bq, bk, dtype):
        q = jax.random.normal(KEYS[2], (B, S, H, D), dtype)
        k = jax.random.normal(KEYS[3], (B, S, K, D), dtype)
        v = jax.random.normal(KEYS[4], (B, S, K, D), dtype)
        out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                     bq=bq, bk=bk, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=causal, window=window)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_matches_model_attention(self):
        """Kernel == the model's chunked online-softmax path."""
        from repro.models.attention import chunked_attention
        B, S, H, K, D = 1, 128, 4, 2, 32
        q = jax.random.normal(KEYS[5], (B, S, H, D), jnp.float32)
        k = jax.random.normal(KEYS[6], (B, S, K, D), jnp.float32)
        v = jax.random.normal(KEYS[7], (B, S, K, D), jnp.float32)
        pos = jnp.arange(S)
        out_model = chunked_attention(q, k, v, q_positions=pos,
                                      kv_positions=pos, causal=True,
                                      window=None, q_block=32, kv_block=32)
        out_kernel = flash_attention_pallas(q, k, v, causal=True,
                                            bq=32, bk=32, interpret=True)
        np.testing.assert_allclose(np.asarray(out_model, np.float32),
                                   np.asarray(out_kernel, np.float32),
                                   rtol=2e-2, atol=2e-2)


class TestDecodeAttention:
    @pytest.mark.parametrize("B,T,K,G,D,window,bt", [
        (2, 512, 2, 4, 64, None, 128),
        (1, 256, 4, 1, 32, 64, 64),
        (3, 1024, 2, 2, 64, None, 256),
        (1, 128, 1, 8, 128, None, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, T, K, G, D, window, bt, dtype):
        q = jax.random.normal(KEYS[0], (B, K, G, D), dtype)
        k = jax.random.normal(KEYS[1], (B, T, K, D), dtype)
        v = jax.random.normal(KEYS[2], (B, T, K, D), dtype)
        idx = jax.random.randint(KEYS[3], (B,), T // 2, T)
        pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        pos = jnp.where(pos <= idx[:, None], pos, -1)
        out = decode_attention_pallas(q, k, v, pos, idx, window=window,
                                      bt=bt, interpret=True)
        ref = decode_attention_ref(q, k, v, pos, idx, window=window)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_empty_cache_slots_masked(self):
        B, T, K, G, D = 1, 64, 1, 2, 16
        q = jax.random.normal(KEYS[4], (B, K, G, D))
        k = jnp.full((B, T, K, D), 1e3)   # poison empty slots
        v = jnp.full((B, T, K, D), 1e3)
        k = k.at[:, :4].set(jax.random.normal(KEYS[5], (B, 4, K, D)))
        v = v.at[:, :4].set(jax.random.normal(KEYS[6], (B, 4, K, D)))
        pos = jnp.full((B, T), -1).at[:, :4].set(jnp.arange(4)[None])
        idx = jnp.array([3])
        out = decode_attention_pallas(q, k, v, pos, idx, bt=32,
                                      interpret=True)
        assert float(jnp.abs(out).max()) < 50.0  # poison never attended
