"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas, paged_decode_attention_pallas)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)
from repro.kernels.flash_attention.kernel import (
    flash_attention_pallas, flash_attention_positions_pallas)
from repro.kernels.flash_attention.ref import (flash_attention_positions_ref,
                                               flash_attention_ref)
from repro.kernels.swarm_uncertainty.kernel import uncertainty_pallas
from repro.kernels.swarm_uncertainty.ref import uncertainty_ref

KEYS = jax.random.split(jax.random.PRNGKey(0), 8)


class TestSwarmUncertainty:
    @pytest.mark.parametrize("B,N,V,bn,bv,k", [
        (2, 16, 4096, 8, 1024, 10),
        (1, 8, 512, 8, 128, 5),
        (3, 32, 8192, 8, 2048, 16),
        (1, 8, 1024, 4, 256, 1),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, N, V, bn, bv, k, dtype):
        logits = (jax.random.normal(KEYS[0], (B, N, V), jnp.float32) * 3
                  ).astype(dtype)
        toks = jax.random.randint(KEYS[1], (B, N), 0, V)
        h, v, hd = uncertainty_pallas(logits, toks, k=k, bn=bn, bv=bv,
                                      interpret=True)
        hr, vr, hdr = uncertainty_ref(logits, toks, k=k)
        tol = 1e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(h, hr, rtol=tol, atol=tol)
        np.testing.assert_allclose(v, vr, rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(hd, hdr, rtol=tol, atol=tol)

    def test_extreme_logits_stable(self):
        logits = jnp.full((1, 8, 512), -1e4).at[..., 0].set(1e4)
        toks = jnp.zeros((1, 8), jnp.int32)
        h, v, hd = uncertainty_pallas(logits, toks, k=4, bv=128,
                                      interpret=True)
        assert np.isfinite(np.asarray(h)).all()
        assert np.isfinite(np.asarray(v)).all()


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,K,D,causal,window,bq,bk", [
        (2, 256, 4, 2, 64, True, None, 64, 64),
        (1, 128, 8, 8, 32, False, None, 64, 32),
        (2, 256, 6, 2, 64, True, 64, 64, 64),      # sliding window
        (1, 512, 4, 1, 128, True, None, 128, 128),  # MQA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, S, H, K, D, causal, window, bq, bk, dtype):
        q = jax.random.normal(KEYS[2], (B, S, H, D), dtype)
        k = jax.random.normal(KEYS[3], (B, S, K, D), dtype)
        v = jax.random.normal(KEYS[4], (B, S, K, D), dtype)
        out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                     bq=bq, bk=bk, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=causal, window=window)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_matches_model_attention(self):
        """Kernel == the model's chunked online-softmax path."""
        from repro.models.attention import chunked_attention
        B, S, H, K, D = 1, 128, 4, 2, 32
        q = jax.random.normal(KEYS[5], (B, S, H, D), jnp.float32)
        k = jax.random.normal(KEYS[6], (B, S, K, D), jnp.float32)
        v = jax.random.normal(KEYS[7], (B, S, K, D), jnp.float32)
        pos = jnp.arange(S)
        out_model = chunked_attention(q, k, v, q_positions=pos,
                                      kv_positions=pos, causal=True,
                                      window=None, q_block=32, kv_block=32)
        out_kernel = flash_attention_pallas(q, k, v, causal=True,
                                            bq=32, bk=32, interpret=True)
        np.testing.assert_allclose(np.asarray(out_model, np.float32),
                                   np.asarray(out_kernel, np.float32),
                                   rtol=2e-2, atol=2e-2)

    @pytest.mark.parametrize("window", [None, 8])
    def test_positions_mode_matches_ref_and_chunked(self, window):
        """Positions-mode kernel (span attends over a live cache, empty
        slots pos = -1) == positions ref == the model's chunked path."""
        from repro.models.attention import chunked_attention
        B, S, T, H, K, D = 2, 8, 32, 4, 2, 32
        q = jax.random.normal(KEYS[2], (B, S, H, D), jnp.float32)
        k = jax.random.normal(KEYS[3], (B, T, K, D), jnp.float32)
        v = jax.random.normal(KEYS[4], (B, T, K, D), jnp.float32)
        # continuation layout: span at positions 20..27, cache holds 0..19
        # plus the span's own slots, tail slots empty (-1)
        qpos = jnp.arange(20, 20 + S, dtype=jnp.int32)
        kvpos = jnp.where(jnp.arange(T) < 28, jnp.arange(T), -1)
        out = flash_attention_positions_pallas(
            q, k, v, q_positions=qpos, kv_positions=kvpos, causal=True,
            window=window, bq=4, bk=8, interpret=True)
        ref = flash_attention_positions_ref(
            q, k, v, q_positions=qpos, kv_positions=kvpos, causal=True,
            window=window)
        ch = chunked_attention(q, k, v, q_positions=qpos, kv_positions=kvpos,
                               causal=True, window=window, q_block=4,
                               kv_block=8)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ch, np.float32),
                                   rtol=2e-2, atol=2e-2)


class TestDecodeAttention:
    @pytest.mark.parametrize("B,T,K,G,D,window,bt", [
        (2, 512, 2, 4, 64, None, 128),
        (1, 256, 4, 1, 32, 64, 64),
        (3, 1024, 2, 2, 64, None, 256),
        (1, 128, 1, 8, 128, None, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, T, K, G, D, window, bt, dtype):
        q = jax.random.normal(KEYS[0], (B, K, G, D), dtype)
        k = jax.random.normal(KEYS[1], (B, T, K, D), dtype)
        v = jax.random.normal(KEYS[2], (B, T, K, D), dtype)
        idx = jax.random.randint(KEYS[3], (B,), T // 2, T)
        pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        pos = jnp.where(pos <= idx[:, None], pos, -1)
        out = decode_attention_pallas(q, k, v, pos, idx, window=window,
                                      bt=bt, interpret=True)
        ref = decode_attention_ref(q, k, v, pos, idx, window=window)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_empty_cache_slots_masked(self):
        B, T, K, G, D = 1, 64, 1, 2, 16
        q = jax.random.normal(KEYS[4], (B, K, G, D))
        k = jnp.full((B, T, K, D), 1e3)   # poison empty slots
        v = jnp.full((B, T, K, D), 1e3)
        k = k.at[:, :4].set(jax.random.normal(KEYS[5], (B, 4, K, D)))
        v = v.at[:, :4].set(jax.random.normal(KEYS[6], (B, 4, K, D)))
        pos = jnp.full((B, T), -1).at[:, :4].set(jnp.arange(4)[None])
        idx = jnp.array([3])
        out = decode_attention_pallas(q, k, v, pos, idx, bt=32,
                                      interpret=True)
        assert float(jnp.abs(out).max()) < 50.0  # poison never attended


class TestPagedDecodeKernel:
    """Block-table kernel: ring layouts, sentinel entries, delta overlay —
    all validated against the gathered-view reference."""

    B, K, G, D, N, L, nb = 2, 2, 2, 32, 16, 8, 4   # Tl = 32

    def _pool_state(self, *, window, p0, sentinel=False):
        """Pool filled linearly up to p0[b] tokens per row (ring slots for
        windowed layers: slot = pos % Tl, wrapped writes land BELOW the
        linear position)."""
        B, K, D, N, L, nb = self.B, self.K, self.D, self.N, self.L, self.nb
        Tl = nb * L
        q = jax.random.normal(KEYS[0], (B, K, self.G, D), jnp.float32)
        k_pool = jax.random.normal(KEYS[1], (N, L, K, D), jnp.float32)
        v_pool = jax.random.normal(KEYS[2], (N, L, K, D), jnp.float32)
        table = jax.random.permutation(KEYS[3], N)[:B * nb].reshape(
            B, nb).astype(jnp.int32)
        if sentinel:
            table = table.at[0, nb - 1].set(N + 7)
        pos_pool = np.full((N, L), -1, np.int32)
        for b in range(B):
            for p in range(int(p0[b])):          # later writes win (ring)
                sl = p % Tl if window is not None else p
                blk = int(table[b, sl // L])
                if blk < N:
                    pos_pool[blk, sl % L] = p
        return q, k_pool, v_pool, jnp.asarray(pos_pool), table

    def _delta(self, p0, t_now, steps=6):
        dk = jax.random.normal(KEYS[4], (self.B, steps, self.K, self.D),
                               jnp.float32)
        dv = jax.random.normal(KEYS[5], (self.B, steps, self.K, self.D),
                               jnp.float32)
        dpos = jnp.where(jnp.arange(steps)[None] <= t_now,
                         p0[:, None] + jnp.arange(steps)[None],
                         -1).astype(jnp.int32)
        return dk, dv, dpos

    @pytest.mark.parametrize("window", [None, 32])
    @pytest.mark.parametrize("sentinel", [False, True])
    def test_delta_overlay_matches_ref(self, window, sentinel):
        Tl = self.nb * self.L
        idx = jnp.array([Tl + 5 if window is not None else Tl - 2,
                         Tl // 2], jnp.int32)
        p0 = idx - 3
        q, k_pool, v_pool, pos_pool, table = self._pool_state(
            window=window, p0=p0, sentinel=sentinel)
        dk, dv, dpos = self._delta(p0, t_now=3)
        out = paged_decode_attention_pallas(
            q, k_pool, v_pool, pos_pool, table, idx, window=window,
            delta_k=dk, delta_v=dv, delta_pos=dpos, p0=p0, interpret=True)
        ref = paged_decode_attention_ref(
            q, k_pool, v_pool, pos_pool, table, idx, window=window,
            delta_k=dk, delta_v=dv, delta_pos=dpos, p0=p0)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-5, atol=2e-5)

    def test_ring_wrap_reads_below_linear_position(self):
        """Windowed ring: index past the view length, writes wrapped — the
        kernel must attend the wrapped slots (positions idx-window+1..idx),
        matching the gathered-view reference on the ring layout."""
        window = self.nb * self.L                # Tl == window ring
        idx = jnp.array([window + 10, window + 3], jnp.int32)
        p0 = idx - 2
        q, k_pool, v_pool, pos_pool, table = self._pool_state(
            window=window, p0=p0)
        dk, dv, dpos = self._delta(p0, t_now=2)
        out = paged_decode_attention_pallas(
            q, k_pool, v_pool, pos_pool, table, idx, window=window,
            delta_k=dk, delta_v=dv, delta_pos=dpos, p0=p0, interpret=True)
        ref = paged_decode_attention_ref(
            q, k_pool, v_pool, pos_pool, table, idx, window=window,
            delta_k=dk, delta_v=dv, delta_pos=dpos, p0=p0)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-5, atol=2e-5)

    def test_sentinel_entries_never_attended(self):
        """Invalid table entries (>= N, empty serve slots) are masked out
        wholesale — poison in the clamped-to block never leaks."""
        Tl = self.nb * self.L
        idx = jnp.array([Tl - 2, Tl // 2], jnp.int32)
        p0 = idx + 1                              # no dispatch writes yet
        q, k_pool, v_pool, pos_pool, table = self._pool_state(
            window=None, p0=p0)
        table = table.at[0, self.nb - 1].set(self.N + 3)
        # poison the block the sentinel clamps to (N - 1) with huge values
        # at valid-looking positions
        k_pool = k_pool.at[self.N - 1].set(1e3)
        v_pool = v_pool.at[self.N - 1].set(1e3)
        out = paged_decode_attention_pallas(
            q, k_pool, v_pool, pos_pool, table, idx, interpret=True)
        ref = paged_decode_attention_ref(
            q, k_pool, v_pool, pos_pool, table, idx)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-5, atol=2e-5)

    def test_gathered_view_equivalence(self):
        """The paged ref matches the monolithic ref on the hand-gathered
        linear view with the delta scattered in — the oracle chain the
        engine parity suite leans on.  (Allclose, not exact: the paged ref
        concatenates delta rows after the view, so softmax summation order
        differs from the in-place scatter.)"""
        Tl = self.nb * self.L
        idx = jnp.array([Tl - 2, Tl // 2], jnp.int32)
        p0 = idx - 3
        q, k_pool, v_pool, pos_pool, table = self._pool_state(
            window=None, p0=p0)
        dk, dv, dpos = self._delta(p0, t_now=3)
        ref = paged_decode_attention_ref(
            q, k_pool, v_pool, pos_pool, table, idx, window=None,
            delta_k=dk, delta_v=dv, delta_pos=dpos, p0=p0)
        # hand-gather the linear view, then scatter the written delta rows
        flat = table.reshape(-1)
        k = jnp.take(k_pool, flat, axis=0).reshape(self.B, Tl, self.K, self.D)
        v = jnp.take(v_pool, flat, axis=0).reshape(self.B, Tl, self.K, self.D)
        pos = jnp.take(pos_pool, flat, axis=0).reshape(self.B, Tl)
        b = jnp.arange(self.B)[:, None]
        sl = jnp.where(dpos >= 0, dpos, Tl)      # slot == position (linear)
        k = k.at[b, sl].set(dk, mode="drop")
        v = v.at[b, sl].set(dv, mode="drop")
        pos = pos.at[b, sl].set(dpos, mode="drop")
        mono = decode_attention_ref(q, k, v, pos, idx)
        np.testing.assert_allclose(np.asarray(ref, np.float32),
                                   np.asarray(mono, np.float32),
                                   rtol=1e-5, atol=1e-5)


class TestBlockSnapping:
    """Grid legality for geometries the old ``min(cap, dim)`` policy
    rejected (ISSUE 9): legal serving shapes whose dimension is not a
    multiple of the default block cap must snap to a dividing block and
    still match the reference — previously these tripped the kernels'
    divisibility asserts on TPU (e.g. a 640-slot cache vs bt=512,
    llama3's 128256-entry vocab vs bv=2048)."""

    def test_snap_block_properties(self):
        from repro.kernels.blocking import snap_block
        for dim in (64, 192, 320, 640, 1280, 49152, 128256, 152064, 202048):
            for cap in (8, 256, 512, 2048):
                b = snap_block(dim, cap)
                assert 1 <= b <= min(cap, dim) and dim % b == 0, (dim, cap)
        # the documented regressions: old policy was min(cap, dim)
        assert 640 % min(512, 640) != 0
        assert 128256 % min(2048, 128256) != 0

    def test_decode_attention_non_multiple_cache_len(self):
        # T=640 is a legal cache length (64-granule growth) with bt=512
        B, T, K, G, D = 2, 640, 2, 2, 32
        q = jax.random.normal(KEYS[0], (B, K, G, D), jnp.float32)
        k = jax.random.normal(KEYS[1], (B, T, K, D), jnp.float32)
        v = jax.random.normal(KEYS[2], (B, T, K, D), jnp.float32)
        idx = jnp.full((B,), T - 1)
        pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        out = decode_attention_pallas(q, k, v, pos, idx, interpret=True)
        ref = decode_attention_ref(q, k, v, pos, idx)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_flash_attention_non_multiple_lengths(self):
        # S=T=320 (a 64-granule length) vs the 256 default tiles
        B, S, H, K, D = 1, 320, 4, 2, 32
        q = jax.random.normal(KEYS[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(KEYS[1], (B, S, K, D), jnp.float32)
        v = jax.random.normal(KEYS[2], (B, S, K, D), jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_uncertainty_non_multiple_vocab(self):
        # V=672 vs an explicit bv=256 cap: snaps to 224
        B, N, V = 2, 8, 672
        logits = jax.random.normal(KEYS[0], (B, N, V), jnp.float32) * 3
        toks = jax.random.randint(KEYS[1], (B, N), 0, V)
        h, v, hd = uncertainty_pallas(logits, toks, k=5, bv=256,
                                      interpret=True)
        hr, vr, hdr = uncertainty_ref(logits, toks, k=5)
        np.testing.assert_allclose(h, hr, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(v, vr, rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(hd, hdr, rtol=1e-4, atol=1e-4)
