"""Distillation feedback loop (paper Sec. IV-H) — implemented end-to-end.

1. Route hard queries; escalations land in the gateway's distill buffer.
2. Fine-tune LoRA adapters on the probe SLM against the cloud FM's teacher
   logits over the buffered queries.
3. Show the probe's hard-query accuracy before vs after distillation.

  PYTHONPATH=src python examples/distill_loop.py [--train-steps 200]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distill import distill_step
from repro.data.workload import is_correct
from repro.models import lora as lora_lib
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.swarm import pad_prompts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--distill-steps", type=int, default=120)
    args = ap.parse_args()

    from repro.launch.serve import build_gateway
    gw, probe, cloud, world = build_gateway(args.train_steps)

    hard = world.hard_queries(24, seed=77)
    prompts = pad_prompts([q["prompt"] for q in hard])

    def accuracy(engine):
        res = engine.generate(prompts, 4)
        return np.mean([is_correct(res["tokens"][i], q["gold"])
                        for i, q in enumerate(hard)])

    acc_before = accuracy(probe)
    print(f"probe hard accuracy before distillation: {acc_before:.2f}")

    # 1. escalations fill the buffer (the gateway logs (Q, M_cloud(Q)))
    gw.answer_batch(hard)
    print(f"distill buffer: {len(gw.distill_buffer.items)} escalated queries")

    # 2. teacher logits from the cloud FM over buffered prompts
    teacher_res = cloud.generate(prompts, 4)
    teacher_logits = teacher_res["logits"]          # (B, N, V)
    gen = teacher_res["tokens"]

    # student sees [prompt | teacher answer]; losses only on answer positions
    full = np.concatenate([prompts, gen], axis=1)
    batch = {
        "tokens": jnp.asarray(full[:, :-1]),
        "labels": jnp.asarray(full[:, 1:]),
        "loss_mask": jnp.concatenate([
            jnp.zeros((len(hard), prompts.shape[1] - 1)),
            jnp.ones((len(hard), gen.shape[1]))], axis=1),
    }
    # teacher logits aligned to answer positions; prompt positions get the
    # student's own labels only (mask selects answers anyway)
    V = probe.cfg.vocab_size
    t_full = jnp.zeros((len(hard), full.shape[1] - 1, V))
    t_full = t_full.at[:, -gen.shape[1]:, :].set(teacher_logits)

    # 3. LoRA distillation (base frozen)
    lora = lora_lib.init_lora(probe.params, jax.random.PRNGKey(9), rank=8)
    for step in range(args.distill_steps):
        lora, loss = distill_step(lora, probe.params, probe.cfg, batch,
                                  t_full, lr=5e-3)
        if step % 40 == 0:
            print(f"  distill step {step}: loss {float(loss):.3f}")

    distilled = InferenceEngine(
        "probe+lora", probe.cfg,
        lora_lib.merge(probe.params, lora, freeze_base=False), probe.ucfg)
    acc_after = accuracy(distilled)
    print(f"probe hard accuracy after distillation:  {acc_after:.2f}")
    print("teacher (cloud FM) hard accuracy:        "
          f"{accuracy(cloud):.2f}")


if __name__ == "__main__":
    main()
