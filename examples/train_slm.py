"""End-to-end training driver for an edge SLM (deliverable b).

Presets:
  tiny   (default) — reduced smollm config, a few hundred steps on CPU
  100m             — the REAL smollm-135m config (30L, d=576); run this on
                     accelerators; on this CPU container it's feasible only
                     with very small batch/seq (documented, not default)

  PYTHONPATH=src python examples/train_slm.py --preset tiny --steps 300
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.data.pipeline import SyntheticLMPipeline
from repro.data.workload import FactWorld
from repro.models import transformer as T
from repro.training import optimizer as opt
from repro.training import train as TR


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.preset == "tiny":
        cfg = dataclasses.replace(C.get_smoke("smollm-135m"), vocab_size=512)
    else:
        cfg = C.get_config("smollm-135m")      # 135M params, real config

    print(f"training {cfg.name}: {cfg.num_params()/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")
    ocfg = opt.AdamWConfig(lr=2e-2 if args.preset == "tiny" else 3e-4,
                           total_steps=args.steps,
                           warmup_steps=max(args.steps // 10, 1),
                           weight_decay=0.0 if args.preset == "tiny" else 0.1)
    step_fn = TR.build_train_step(cfg, ocfg, None)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    pipe = SyntheticLMPipeline(args.batch, args.seq,
                               world=FactWorld(n_ent=16, n_rel=6))
    t0 = time.time()
    for s in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in pipe.get_batch(s).items()}
        params, state, m = step_fn(params, state, b)
        if s % 50 == 0 or s == args.steps - 1:
            tput = args.batch * args.seq * (s + 1) / (time.time() - t0)
            print(f"step {s:4d} loss {float(m['loss']):.4f} "
                  f"({tput:,.0f} tok/s)", flush=True)
        if args.ckpt_dir and (s + 1) % 100 == 0:
            from repro.training import checkpoint as ck
            ck.save(args.ckpt_dir, s + 1, {"params": params, "opt": state},
                    extra={"step": s + 1})


if __name__ == "__main__":
    main()
