"""End-to-end reproduction of the paper's Sec. VII study (Tables III-V).

Builds the full three-tier system (3 heterogeneous edge SLMs + cloud FM +
safety classifier), routes the 50-query study workload, and prints the three
tables side-by-side with the paper's numbers.

  PYTHONPATH=src python examples/study_workload.py [--train-steps 300]
"""

import argparse
import sys

sys.path.insert(0, ".")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--quorum", type=int, default=None,
                    help="beyond-paper: wait for fastest-k peers only")
    args = ap.parse_args()

    from benchmarks.tables import PAPER, run_study
    res = run_study(train_steps=args.train_steps, quorum=args.quorum)

    p3 = PAPER["table3"]
    print("\n=== Table III: latency & cloud usage (ours | paper) ===")
    rows = [("Edge-Only", "edge", "edge"), ("Cloud-Only", "cloud", "cloud"),
            ("SWARM-LLM", "swarm", "swarm")]
    for name, k, pk in rows:
        t = res["table3"][k]
        pm = p3.get(f"{pk}_mean", float("nan"))
        pp = p3.get(f"{pk}_p95", float("nan"))
        print(f"{name:11s} mean {t['mean']:5.2f}s | {pm:5.2f}s   "
              f"p95 {t['p95']:5.2f}s | {pp:5.2f}s   "
              f"cloud {t['cloud_usage']*100:5.1f}%")

    p4 = PAPER["table4"]
    print("\n=== Table IV: accuracy (ours | paper) ===")
    for name, k in [("Edge-Only", "edge"), ("Cloud-Only", "cloud"),
                    ("SWARM-LLM", "swarm")]:
        a = res["table4"][k]
        pa = p4[k]
        print(f"{name:11s} overall {a['overall']:.3f}|{pa[0]:.3f}  "
              f"easy {a['easy']:.2f}|{pa[1]:.2f}  "
              f"hard {a['hard']:.2f}|{pa[2]:.2f}")

    p5 = PAPER["table5"]
    print("\n=== Table V: privacy, normalised to cloud-only (ours | paper) ===")
    for k in ("CER", "TER", "SER"):
        print(f"{k}: {res['table5'][k]:.3f} | {p5[k]:.3f}")

    print(f"\nsummoning rate: {res['summoning_rate']*100:.1f}% "
          f"(paper: ~28%)   distill buffer: {res['distill_buffer']} queries")


if __name__ == "__main__":
    main()
