"""Quickstart: the SWARM-LLM core API in ~60 lines.

Trains a tiny edge SLM, computes the paper's uncertainty score (Eq. 2-4)
for easy vs hard queries, runs the weighted consensus (Eq. 14) and the
threshold router (Algorithm 1).

  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.core import budget, router
from repro.core.consensus import weighted_consensus
from repro.core.uncertainty import UncertaintyConfig
from repro.data.pipeline import SyntheticLMPipeline
from repro.data.workload import FactWorld
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.swarm import pad_prompts
from repro.training import optimizer as opt
from repro.training import train as TR

# --- 1. train a tiny edge SLM on 1-hop facts -------------------------------
world = FactWorld(n_ent=16, n_rel=6)
cfg = dataclasses.replace(C.get_smoke("swarm-edge-1b"), vocab_size=512)
step = TR.build_train_step(cfg, opt.AdamWConfig(lr=2e-2, total_steps=400), None)
params = T.init_params(cfg, jax.random.PRNGKey(0))
state = opt.init(params)
pipe = SyntheticLMPipeline(16, 64, world=world)
for s in range(400):
    b = {k: jnp.asarray(v) for k, v in pipe.get_batch(s).items()}
    params, state, m = step(params, state, b)
print(f"trained edge SLM, loss {float(m['loss']):.3f}")

# --- 2. difficulty scores (paper Eq. 2-4) -----------------------------------
engine = InferenceEngine("edge", cfg, params,
                         UncertaintyConfig(alpha=1.0, mode="distribution"))
easy = world.easy_queries(8, seed=41)
hard = world.hard_queries(8, seed=42)
res_e = engine.generate(pad_prompts([q["prompt"] for q in easy]), 4)
res_h = engine.generate(pad_prompts([q["prompt"] for q in hard]), 4)
print(f"U(easy) = {res_e['u'].mean():.3f}   U(hard) = {res_h['u'].mean():.3f}")

# --- 3. consensus over three 'peers' (Eq. 14) -------------------------------
answers = jnp.array([[301, 5, 0, 0], [301, 5, 0, 0], [299, 5, 0, 0]])
u = jnp.array([0.2, 0.3, 0.8])
cons = weighted_consensus(answers, u)
print(f"consensus: cluster score {float(cons.best_score):.2f}, "
      f"winner = member {int(cons.rep_index)}")

# --- 4. threshold routing (Algorithm 1) -------------------------------------
u_batch = jnp.concatenate([jnp.asarray(res_e["u"]), jnp.asarray(res_h["u"])])
s_batch = jnp.zeros_like(u_batch)              # no safety risk here
rc = router.RouterConfig(tau_low=float(np.quantile(u_batch, 0.4)),
                         tau_high=float(np.quantile(u_batch, 0.75)))
out = router.route(u_batch, s_batch, cfg=rc, budget=budget.init_budget(1.0),
                   wan_ok=True, est_cloud_cost=jnp.full_like(u_batch, 1e-4))
names = np.array(router.DECISION_NAMES)[out.decision]
print("decisions:", dict(zip(*np.unique(names, return_counts=True))))
