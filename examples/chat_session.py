"""Multi-turn chat sessions over a live cache (ISSUE 4's new workload).

Turn t+1 continues turn t's attention/recurrent caches: each turn pays ONE
continuation prefill of just the new user tokens instead of re-absorbing the
whole conversation — the canonical edge-serving lever for chat (prefix-cache
reuse; see docs/RUNTIME.md "Continuation prefill & session caches").

Three demonstrations on an (untrained) smoke SLM:

  1. batched sessions through ``generate(state=...)``, verified against a
     cold re-prefill of the full conversation each turn;
  2. the same sessions streamed through ``serve()`` with warm admissions
     (``Request.state`` / ``return_state``);
  3. the timing gap cold vs warm as the conversation grows.

  PYTHONPATH=src python examples/chat_session.py

``--shared-system-prompt`` adds a fourth demonstration on the PAGED engine
(docs/RUNTIME.md "Paged caches & prefix sharing"): one absorbed system
prompt fanned out to many sessions by copy-on-write block tables — one
prefill total — verified against per-session cold prefills and timed.

  PYTHONPATH=src python examples/chat_session.py --shared-system-prompt

``--attn-decode-impl {kernel,gather}`` selects the paged engine's decode-
attention path (default: measured-best per backend — the in-place
block-table kernel; see docs/RUNTIME.md "Kernel-first decode"),
``--cache-quant {int8,fp8}`` stores its KV blocks quantized (same greedy
tokens under the budgeted-parity contract of docs/RUNTIME.md "Quantized
caches"), and ``--compilation-cache-dir DIR`` persists every XLA
executable so a re-run of this script skips all compilation.
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs as C
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import Request
from repro.serving.swarm import pad_prompts

ap = argparse.ArgumentParser()
ap.add_argument("--shared-system-prompt", action="store_true")
ap.add_argument("--attn-decode-impl", choices=("kernel", "gather"),
                default=None)
ap.add_argument("--cache-quant", choices=("int8", "fp8"), default=None,
                help="store the paged engine's KV blocks quantized "
                     "(docs/RUNTIME.md 'Quantized caches')")
ap.add_argument("--compilation-cache-dir", default=None)
args = ap.parse_args()

cfg = dataclasses.replace(C.get_smoke("smollm-135m"), vocab_size=512)
eng = InferenceEngine("chat", cfg,
                      params=T.init_params(cfg, jax.random.PRNGKey(0)),
                      compilation_cache_dir=args.compilation_cache_dir)

rng = np.random.RandomState(7)
MAX_NEW = 8


def user_turn(t: int, b: int) -> list[int]:
    """A synthetic user message (token ids) for session b, turn t."""
    return rng.randint(7, cfg.vocab_size, size=4 + (t + b) % 3).tolist()


# --- 1. batched multi-turn sessions over one warm cache ---------------------
B = 3
opening = pad_prompts([user_turn(0, b) for b in range(B)])
res = eng.generate(opening, MAX_NEW, return_state=True)
history = opening
print(f"turn 0: prefilled {opening.shape[1]} tokens "
      f"-> answers {res['tokens'].shape}")
for t in range(1, 4):
    span = pad_prompts([user_turn(t, b) for b in range(B)])
    history = np.concatenate([history, res["tokens"], span], axis=1)
    res = eng.generate(span, MAX_NEW, state=res["state"], return_state=True)
    cold = eng.generate(history, MAX_NEW)       # re-absorbs everything
    agree = np.array_equal(res["tokens"], cold["tokens"])
    print(f"turn {t}: continuation prefill of {span.shape[1]} new tokens "
          f"(history {history.shape[1]}) -> matches cold re-prefill: {agree}")

# --- 2. the same sessions through streaming serve() -------------------------
fin = eng.serve([Request(rid=b, prompt=[int(x) for x in opening[b]],
                         max_new=MAX_NEW, return_state=True)
                 for b in range(B)], n_slots=2)
states = {r["rid"]: r["state"] for r in fin}
fin2 = eng.serve([Request(rid=b, prompt=user_turn(1, b), max_new=MAX_NEW,
                          state=states[b]) for b in range(B)], n_slots=2)
print(f"serve(): {len(fin)} sessions opened, {len(fin2)} warm follow-ups "
      f"(admissions continuation-prefilled only the new turn)")

# --- 3. cold vs warm as the conversation grows ------------------------------
long_ctx = rng.randint(7, cfg.vocab_size, size=(4, 192)).astype(np.int32)
turn = rng.randint(7, cfg.vocab_size, size=(4, 8)).astype(np.int32)


def run(n_turns: int, warm: bool) -> float:
    r = eng.generate(long_ctx, MAX_NEW, return_state=warm)
    h = long_ctx
    t0 = time.perf_counter()
    for _ in range(n_turns):
        if warm:
            r = eng.generate(turn, MAX_NEW, state=r["state"],
                             return_state=True)
        else:
            h = np.concatenate([h, r["tokens"], turn], axis=1)
            r = eng.generate(h, MAX_NEW)
    return time.perf_counter() - t0


run(2, False), run(2, True)                     # compile both paths
cold_s, warm_s = run(3, False), run(3, True)
print(f"3 follow-up turns on a {long_ctx.shape[1]}-token context: "
      f"cold {cold_s*1e3:.0f} ms, warm {warm_s*1e3:.0f} ms "
      f"({cold_s/warm_s:.1f}x)")

# --- 4. (--shared-system-prompt) paged COW fan-out of one absorbed prefix --
if args.shared_system_prompt:
    N_SESS = 8
    paged = InferenceEngine("chat-paged", cfg, params=eng.params,
                            paged=True, block_len=32, pool_blocks=512,
                            attn_decode_impl=args.attn_decode_impl,
                            cache_quant=args.cache_quant,
                            compilation_cache_dir=args.compilation_cache_dir)
    sys_prompt = rng.randint(7, cfg.vocab_size, size=(1, 448)).astype(np.int32)

    def shared():
        st = paged.absorb(sys_prompt)            # ONE prefill, total
        fan = paged.fanout(st, N_SESS)           # refcounted block tables
        out = paged.generate(None, MAX_NEW, state=fan)["tokens"]
        paged.release(fan); paged.release(st)
        return out

    def cold_each():
        return eng.generate(np.tile(sys_prompt, (N_SESS, 1)),
                            MAX_NEW)["tokens"]

    shared(), cold_each()                        # compile both paths
    pc0 = paged.counters["prefill"]
    t0 = time.perf_counter(); toks_s = shared()
    t_shared = time.perf_counter() - t0
    t0 = time.perf_counter(); toks_c = cold_each()
    t_cold = time.perf_counter() - t0
    agree = np.array_equal(toks_s, toks_c)
    print(f"shared system prompt ({sys_prompt.shape[1]} tokens) -> "
          f"{N_SESS} sessions: {paged.counters['prefill'] - pc0} prefill "
          f"dispatch(es) on the paged engine; matches per-session cold "
          f"prefill: {agree}; shared {t_shared*1e3:.0f} ms vs cold "
          f"{t_cold*1e3:.0f} ms ({t_cold/t_shared:.1f}x); "
          f"COW copies: {paged.pool.counters['cow_copies']}")
