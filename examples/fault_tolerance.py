"""Fault-tolerance demo: checkpoint/restart + O5 degradation + quorum.

1. Train with checkpoints, kill mid-run (simulated), resume — identical
   final loss to an uninterrupted run (deterministic pipeline replay).
2. WAN outage: gateway degrades cloud -> swarm -> local, zero failures.
3. Straggler mitigation: quorum-2 swarm latency vs full-swarm (Eq. 9).

  PYTHONPATH=src python examples/fault_tolerance.py
"""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.core.cost_model import LatencyParams, latency_swarm
from repro.data.pipeline import SyntheticLMPipeline
from repro.models import transformer as T
from repro.training import checkpoint as ck
from repro.training import optimizer as opt
from repro.training import train as TR


def train_segment(cfg, params, state, step_fn, pipe, start, end):
    for s in range(start, end):
        b = {k: jnp.asarray(v) for k, v in pipe.get_batch(s).items()}
        params, state, m = step_fn(params, state, b)
    return params, state, float(m["loss"])


def main():
    # --- 1. checkpoint / restart determinism -----------------------------
    cfg = dataclasses.replace(C.get_smoke("smollm-135m"), vocab_size=512)
    ocfg = opt.AdamWConfig(lr=5e-3, total_steps=60)
    pipe = SyntheticLMPipeline(8, 64)

    def fresh():
        p = T.init_params(cfg, jax.random.PRNGKey(0))
        return p, opt.init(p), TR.build_train_step(cfg, ocfg, None,
                                                   donate=False)

    p, s, fn = fresh()
    p, s, loss_uninterrupted = train_segment(cfg, p, s, fn, pipe, 0, 60)

    p, s, fn = fresh()
    p, s, _ = train_segment(cfg, p, s, fn, pipe, 0, 30)
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 30, {"params": p, "opt": s}, extra={"step": 30})
        print("checkpoint written at step 30 — simulating crash + restart")
        del p, s
        abs_p = T.abstract_params(cfg)
        tree, extra = ck.restore(d, ck.latest_step(d),
                                 {"params": abs_p,
                                  "opt": opt.abstract_state(abs_p)})
    p2, s2 = tree["params"], tree["opt"]
    p2, s2, loss_resumed = train_segment(cfg, p2, s2, fn, pipe,
                                         extra["step"], 60)
    print(f"final loss uninterrupted {loss_uninterrupted:.4f} vs "
          f"resumed {loss_resumed:.4f} "
          f"(delta {abs(loss_uninterrupted - loss_resumed):.5f})")

    # --- 2. WAN outage degradation (O5) -----------------------------------
    from repro.core.router import CLOUD, CLOUD_SAFETY
    from repro.launch.serve import build_gateway
    from repro.serving.simulator import NetworkSimulator, SimConfig
    gw, probe, cloud, world = build_gateway(train_steps=60)
    gw.sim = NetworkSimulator(SimConfig(wan_outage_p=1.0, wan_recover_p=0.0),
                              LatencyParams(), n_members=3)
    log = gw.answer_batch(world.study_workload(6, 6, 4))
    n_cloud = int(np.isin(log.decision, (CLOUD, CLOUD_SAFETY)).sum())
    print(f"WAN down: {len(log.decision)} queries answered, "
          f"{n_cloud} reached cloud (expected 0)")

    # --- 3. quorum straggler mitigation ------------------------------------
    rng = np.random.RandomState(0)
    edge = rng.lognormal(0, 0.4, (2000, 3)) + 0.5
    comm = np.abs(rng.normal(0.15, 0.08, (2000, 3)))
    lat = LatencyParams()
    full = np.asarray(latency_swarm(jnp.asarray(edge), jnp.asarray(comm), lat))
    q2 = np.asarray(latency_swarm(jnp.asarray(edge), jnp.asarray(comm), lat,
                                  quorum=2))
    print(f"swarm p99 latency: full {np.percentile(full, 99):.2f}s vs "
          f"quorum-2 {np.percentile(q2, 99):.2f}s "
          f"({(1 - np.percentile(q2, 99)/np.percentile(full, 99))*100:.0f}% "
          "tail reduction)")


if __name__ == "__main__":
    main()
