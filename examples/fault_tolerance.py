"""Fault-tolerance demo: checkpoints + execution-level chaos + quorum.

1. Train with checkpoints, kill mid-run (simulated), resume — identical
   final loss to an uninterrupted run (deterministic pipeline replay).
2. Session durability: checkpoint a live chat, restart the engine,
   resume bitwise (serving-side analogue of 1).
3. Execution-level fault injection (serving/faults.py FaultPlan): a dead
   cloud (summon retries, circuit breaker, O5 degradation), a member
   crashing mid-round (quorum salvage), and an injected straggler — the
   gateway answers EVERY query in all three scenarios.
4. Straggler mitigation: quorum-2 swarm latency vs full-swarm (Eq. 9).

  PYTHONPATH=src python examples/fault_tolerance.py
"""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.core.cost_model import LatencyParams, latency_swarm
from repro.data.pipeline import SyntheticLMPipeline
from repro.models import transformer as T
from repro.training import checkpoint as ck
from repro.training import optimizer as opt
from repro.training import train as TR


def train_segment(cfg, params, state, step_fn, pipe, start, end):
    for s in range(start, end):
        b = {k: jnp.asarray(v) for k, v in pipe.get_batch(s).items()}
        params, state, m = step_fn(params, state, b)
    return params, state, float(m["loss"])


def main():
    # --- 1. checkpoint / restart determinism -----------------------------
    cfg = dataclasses.replace(C.get_smoke("smollm-135m"), vocab_size=512)
    ocfg = opt.AdamWConfig(lr=5e-3, total_steps=60)
    pipe = SyntheticLMPipeline(8, 64)

    def fresh():
        p = T.init_params(cfg, jax.random.PRNGKey(0))
        return p, opt.init(p), TR.build_train_step(cfg, ocfg, None,
                                                   donate=False)

    p, s, fn = fresh()
    p, s, loss_uninterrupted = train_segment(cfg, p, s, fn, pipe, 0, 60)

    p, s, fn = fresh()
    p, s, _ = train_segment(cfg, p, s, fn, pipe, 0, 30)
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 30, {"params": p, "opt": s}, extra={"step": 30})
        print("checkpoint written at step 30 — simulating crash + restart")
        del p, s
        abs_p = T.abstract_params(cfg)
        tree, extra = ck.restore(d, ck.latest_step(d),
                                 {"params": abs_p,
                                  "opt": opt.abstract_state(abs_p)})
    p2, s2 = tree["params"], tree["opt"]
    p2, s2, loss_resumed = train_segment(cfg, p2, s2, fn, pipe,
                                         extra["step"], 60)
    print(f"final loss uninterrupted {loss_uninterrupted:.4f} vs "
          f"resumed {loss_resumed:.4f} "
          f"(delta {abs(loss_uninterrupted - loss_resumed):.5f})")

    # --- 2. session durability: restart the ENGINE mid-chat ---------------
    from repro.core.uncertainty import UncertaintyConfig
    from repro.serving.engine import InferenceEngine

    def serving_engine():
        sp = T.init_params(cfg, jax.random.PRNGKey(1))
        return InferenceEngine("chat", cfg, sp,
                               UncertaintyConfig(mode="distribution"),
                               paged=True, block_len=16)

    e1 = serving_engine()
    st = e1.generate(np.array([[3, 20, 195, 2]], np.int32), 4,
                     return_state=True)["state"]
    turn2 = np.array([[9, 4, 2]], np.int32)
    with tempfile.TemporaryDirectory() as d:
        e1.checkpoint_session(st, d)
        ref = e1.generate(turn2, 4, state=st)["tokens"]
        e2 = serving_engine()                 # the "restarted" process
        resumed = e2.generate(turn2, 4, state=e2.restore_session(d))["tokens"]
    print(f"session restored across engine restart: resumed turn matches "
          f"uninterrupted chat = {bool((ref == resumed).all())}")

    # --- 3. execution-level chaos through the gateway ---------------------
    from repro.core.router import CLOUD, CLOUD_SAFETY
    from repro.launch.serve import build_gateway
    from repro.serving.faults import FaultEvent, FaultPlan
    from repro.serving.simulator import NetworkSimulator, SimConfig
    gw, probe, cloud, world = build_gateway(train_steps=60)
    gw.sim = NetworkSimulator(SimConfig(wan_outage_p=0.0), LatencyParams(),
                              n_members=len(gw.swarm.members))
    qs = world.study_workload(6, 6, 4)
    # a dead cloud forces safety escalations to REFUSE (the O5-safe policy
    # outcome, but still a degradation) — the zero-failures claim is for
    # answerable work, so the outage scenario runs the non-safety slice
    qs_no_safety = world.study_workload(6, 6, 0)

    def chaos(name, plan, queries):
        gw.faults = plan
        gw.swarm.faults = plan
        gw.reset_fault_state()
        log = gw.answer_batch(queries)
        fc = log.faults
        assert log.availability() == 1.0, f"{name}: dropped queries!"
        print(f"{name}: {len(log.decision)} queries, 0 failed "
              f"(availability {log.availability():.2f}; "
              f"retries {fc['cloud_retries']}, breaker {fc['breaker_opened']},"
              f" casualties {fc['member_casualties']}, "
              f"straggle {fc['member_straggle_s']:.1f}s)")
        return log

    # 3a. cloud outage: every summon times out -> retried, breaker opens,
    # O5 degrades cloud aspirants to their swarm/local candidates
    log = chaos("cloud outage",
                FaultPlan([FaultEvent("cloud", "timeout", count=999)]),
                qs_no_safety)
    n_cloud = int(np.isin(log.decision, (CLOUD, CLOUD_SAFETY)).sum())
    print(f"  -> {n_cloud} queries reached cloud (expected 0)")
    # 3b. member 1 crashes mid-round: survivors' consensus salvages it
    chaos("member crash",
          FaultPlan([FaultEvent("member:1", "crash", count=999)]), qs)
    # 3c. injected straggler: answers unchanged, delay hits Eq. 9 latency
    chaos("straggler",
          FaultPlan([FaultEvent("member:2", "straggle", count=999,
                                delay_s=2.0)]), qs)
    gw.faults = gw.swarm.faults = None

    # --- 4. quorum straggler mitigation ------------------------------------
    rng = np.random.RandomState(0)
    edge = rng.lognormal(0, 0.4, (2000, 3)) + 0.5
    comm = np.abs(rng.normal(0.15, 0.08, (2000, 3)))
    lat = LatencyParams()
    full = np.asarray(latency_swarm(jnp.asarray(edge), jnp.asarray(comm), lat))
    q2 = np.asarray(latency_swarm(jnp.asarray(edge), jnp.asarray(comm), lat,
                                  quorum=2))
    print(f"swarm p99 latency: full {np.percentile(full, 99):.2f}s vs "
          f"quorum-2 {np.percentile(q2, 99):.2f}s "
          f"({(1 - np.percentile(q2, 99)/np.percentile(full, 99))*100:.0f}% "
          "tail reduction)")


if __name__ == "__main__":
    main()
