"""Benchmark harness — one function per paper table + microbenchmarks.

Prints ``name,us_per_call,derived`` CSV.  Set BENCH_FAST=1 for a quick pass
(fewer training steps for the study tables), BENCH_FORCE=1 to ignore the
cached study results.
"""

from __future__ import annotations

import os
import sys


def main() -> None:
    rows: list[tuple[str, object, object]] = []

    # --- microbenchmarks -------------------------------------------------
    from benchmarks import micro
    rows += [(n, round(us, 1), d) for n, us, d in micro.bench_all()]

    # --- paper tables (III, IV, V) ---------------------------------------
    from benchmarks import tables
    fast = os.environ.get("BENCH_FAST") == "1"
    force = os.environ.get("BENCH_FORCE") == "1"
    res = tables.cached_study(train_steps=120 if fast else 300, force=force)
    rows += tables.emit_rows(res)

    # --- roofline (from dry-run artifacts, if present) --------------------
    try:
        from benchmarks import roofline
        rl = roofline.analyse()
        rows += roofline.emit_rows(rl)
        os.makedirs("experiments", exist_ok=True)
        with open("experiments/roofline.md", "w") as f:
            f.write(roofline.markdown_table(rl))
            f.write("\n\n## Hillclimb variants (baseline v0 vs optimized)\n\n")
            f.write(roofline.hillclimb_table() + "\n")
    except Exception as e:  # noqa: BLE001
        print(f"# roofline skipped: {e}", file=sys.stderr)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
