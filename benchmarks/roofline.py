"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
per chip.  Per (arch x shape x mesh):

  compute_term    = corrected FLOPs/device   / peak_flops
  memory_term     = corrected bytes/device   / hbm_bw
  collective_term = corrected coll-bytes/dev / link_bw

and MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference) per device
for the usefulness ratio.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

CHIPS = {"single": 256, "multi": 512}


def model_flops_per_device(rec: dict, shape_kind: str, seq_len: int,
                           batch: int, chips: int) -> float:
    n = rec["active_params"]
    if shape_kind == "train":
        tokens = seq_len * batch
        return 6 * n * tokens / chips
    if shape_kind == "prefill":
        tokens = seq_len * batch
        return 2 * n * tokens / chips
    return 2 * n * batch / chips          # decode: one token per request


def analyse(out_dir: str = "experiments/dryrun") -> list[dict]:
    from repro import configs as C
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("skipped") or not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"],
                         "skip": rec.get("skipped") or rec.get("error")})
            continue
        shape = C.SHAPES[rec["shape"]]
        chips = CHIPS[rec["mesh"]]
        c = rec["corrected"]
        t_comp = c["flops_per_device"] / PEAK_FLOPS
        t_mem = c["bytes_per_device"] / HBM_BW
        t_coll = c["collective_bytes_per_device"] / LINK_BW
        dom = max((t_comp, "compute"), (t_mem, "memory"),
                  (t_coll, "collective"))[1]
        mf = model_flops_per_device(rec, shape.kind, shape.seq_len,
                                    shape.global_batch, chips)
        bound = max(t_comp, t_mem, t_coll)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
            "dominant": dom,
            "model_flops_ratio": mf / max(c["flops_per_device"], 1.0),
            "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
            "peak_hbm_gb": rec["full"]["memory"]["peak_est"] / 1e9,
            "hbm_ok": rec["full"]["memory"]["peak_est"] < 16e9,
        })
    return rows


def emit_rows(rows):
    out = []
    for r in rows:
        if "skip" in r:
            continue
        cell = f"{r['arch']}__{r['shape']}__{r['mesh']}"
        out.append((f"roofline_{cell}_dominant_{r['dominant']}", "",
                    round(max(r["compute_s"], r["memory_s"],
                              r["collective_s"]), 6)))
        out.append((f"roofline_{cell}_fraction", "",
                    round(r["roofline_fraction"], 4)))
    return out


def next_lever(r: dict) -> str:
    """One sentence: what would move the dominant term down (per cell)."""
    dom, shape = r["dominant"], r["shape"]
    kind = ("train" if shape.startswith("train")
            else "prefill" if shape.startswith("prefill") else "decode")
    if dom == "collective":
        if kind == "decode":
            return ("stop re-gathering FSDP weight shards per token: "
                    "SERVE_RULES TP-resident weights (+f8) — see §Perf")
        if kind == "prefill":
            return ("overlap TP all-reduces with the next layer's GEMMs "
                    "(latency-hiding scheduler) or widen to 2D TP")
        return ("reduce-scatter grads instead of all-reduce + int8 "
                "error-feedback compression on the pod axis")
    if dom == "memory":
        if kind == "train":
            return ("fewer remat recomputes via dots-saveable policy, or "
                    "shard_map-local MoE dispatch (done for MoE cells)")
        if kind == "decode":
            return ("f8/int8 KV + weights (halves resident bytes); fuse "
                    "decode attention so cache is read once (Pallas kernel)")
        return ("fuse attention/FFN epilogues (Pallas) to cut HBM "
                "round-trips between blocks")
    return ("raise arithmetic intensity: larger per-device microbatch or "
            "MXU-aligned block shapes in the Pallas kernels")


def markdown_table(rows) -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "dominant | 6ND/HLO | roofline frac | peak HBM GB | "
             "what would move the dominant term |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— skipped: {r['skip']} ||||||||")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['dominant']} "
            f"| {r['model_flops_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['peak_hbm_gb']:.1f}{'' if r['hbm_ok'] else ' ⚠'} "
            f"| {next_lever(r)} |")
    return "\n".join(lines)


def hillclimb_table(hc_dir: str = "experiments/hillclimb",
                    base_dir: str = "experiments/dryrun_v0") -> str:
    """Baseline-vs-variant comparison for the §Perf cells."""
    lines = ["| cell | variant | compute s | memory s | collective s | "
             "peak GB |", "|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(hc_dir, "*.json"))):
        rec = json.load(open(path))
        if not rec.get("ok"):
            continue
        cell = f"{rec['arch']} × {rec['shape']} × {rec['mesh']}"
        base_path = os.path.join(
            base_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
        if os.path.exists(base_path):
            b = json.load(open(base_path))
            if b.get("ok"):
                c = b["corrected"]
                lines.append(
                    f"| {cell} | baseline (v0) | "
                    f"{c['flops_per_device']/PEAK_FLOPS:.3f} | "
                    f"{c['bytes_per_device']/HBM_BW:.3f} | "
                    f"{c['collective_bytes_per_device']/LINK_BW:.3f} | "
                    f"{b['full']['memory']['peak_est']/1e9:.1f} |")
        c = rec["corrected"]
        lines.append(
            f"| {cell} | **{rec.get('variant')}** | "
            f"{c['flops_per_device']/PEAK_FLOPS:.3f} | "
            f"{c['bytes_per_device']/HBM_BW:.3f} | "
            f"{c['collective_bytes_per_device']/LINK_BW:.3f} | "
            f"{rec['full']['memory']['peak_est']/1e9:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = analyse()
    print(markdown_table(rows))
    print()
    print(hillclimb_table())
