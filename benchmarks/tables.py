"""Paper-table benchmarks: Tables III, IV, V + Table I sensitivity sweep.

One gateway build (real trained tiers + real routing code, simulated link
timings) feeds all tables; results are cached to experiments/tables.json so
`python -m benchmarks.run` stays cheap on re-runs.
"""

from __future__ import annotations

import json
import os

import numpy as np

PAPER = {  # anchors from the paper (for the comparison column)
    "table3": {"edge_mean": 1.05, "edge_p95": 2.28, "cloud_mean": 4.47,
               "cloud_p95": 11.33, "swarm_mean": 5.08, "swarm_p95": 13.18,
               "swarm_cloud_usage": 0.28},
    "table4": {"edge": (0.225, 0.45, 0.00), "cloud": (0.475, 0.65, 0.30),
               "swarm": (0.250, 0.35, 0.15)},
    "table5": {"CER": 0.280, "TER": 0.413, "SER": 0.800},
}


def run_study(train_steps: int = 300, seed: int = 0,
              quorum: int | None = None) -> dict:
    from repro.data.workload import FactWorld
    from repro.launch.serve import build_gateway
    from repro.serving.gateway import run_cloud_only, run_edge_only

    gw, probe, cloud, world = build_gateway(train_steps, quorum=quorum,
                                            seed=seed)
    queries = world.study_workload()
    log = gw.answer_batch(queries)
    # baselines graded on the SAME answer normalisation as the gateway
    stop = gw.swarm.stop_token
    edge = run_edge_only(queries, probe, gw.sim, stop_token=stop)
    cl = run_cloud_only(queries, cloud, gw.sim, stop_token=stop)

    def t3(lg):
        return {"mean": float(lg.latency.mean()),
                "p95": float(np.percentile(lg.latency, 95)),
                "cloud_usage": lg.cloud_usage(),
                "cost_per_1k": float(lg.cost.sum() / len(lg.latency) * 1000)}

    def t4(lg):
        return {"overall": lg.accuracy(), "easy": lg.accuracy("easy"),
                "hard": lg.accuracy("hard")}

    pm = log.privacy()
    decisions = np.bincount(log.decision, minlength=5).tolist()
    return {
        "table3": {"edge": t3(edge), "cloud": t3(cl), "swarm": t3(log)},
        "table4": {"edge": t4(edge), "cloud": t4(cl), "swarm": t4(log)},
        "table5": {"CER": float(pm.cer), "TER": float(pm.ter),
                   "SER": float(pm.ser)},
        "decisions": decisions,
        "summoning_rate": float(np.mean((log.decision == 2)
                                        | (log.decision == 3))),
        "mean_consensus": float(np.nanmean(log.consensus))
        if not np.all(np.isnan(log.consensus)) else None,
        "distill_buffer": len(gw.distill_buffer.items),
    }


def cached_study(path: str = "experiments/tables.json",
                 train_steps: int = 300, force: bool = False) -> dict:
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    res = run_study(train_steps)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return res


def emit_rows(res: dict):
    """CSV rows (name, us_per_call, derived) for benchmarks.run."""
    rows = []
    for arch in ("edge", "cloud", "swarm"):
        t = res["table3"][arch]
        rows.append((f"table3_{arch}_mean_latency_s", "", t["mean"]))
        rows.append((f"table3_{arch}_p95_latency_s", "", t["p95"]))
        rows.append((f"table3_{arch}_cloud_usage", "", t["cloud_usage"]))
        rows.append((f"table3_{arch}_cost_per_1k_usd", "", t["cost_per_1k"]))
        a = res["table4"][arch]
        rows.append((f"table4_{arch}_acc_overall", "", a["overall"]))
        rows.append((f"table4_{arch}_acc_easy", "", a["easy"]))
        rows.append((f"table4_{arch}_acc_hard", "", a["hard"]))
    for k, v in res["table5"].items():
        rows.append((f"table5_{k.lower()}_norm", "", v))
        rows.append((f"table5_{k.lower()}_paper", "", PAPER["table5"][k]))
    rows.append(("summoning_rate", "", res["summoning_rate"]))
    return rows
