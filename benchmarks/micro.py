"""Microbenchmarks of the SWARM-LLM hot paths (CPU timings, us/call)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_all() -> list[tuple[str, float, float]]:
    rows = []
    key = jax.random.PRNGKey(0)

    # uncertainty probe (jnp oracle path; the Pallas kernel is TPU-target)
    from repro.core.uncertainty import UncertaintyConfig, difficulty_jit
    B, N, V = 8, 16, 49152
    logits = jax.random.normal(key, (B, N, V), jnp.float32)
    toks = jax.random.randint(key, (B, N), 0, V)
    ucfg = UncertaintyConfig()
    us = _time(difficulty_jit, logits, toks, ucfg)
    rows.append(("uncertainty_probe_8x16x49k", us, B * N))

    # consensus (Eq. 14)
    from repro.core.consensus import batched_consensus
    ans = jax.random.randint(key, (64, 4, 8), 0, 16)
    u = jax.random.uniform(key, (64, 4))
    f = jax.jit(lambda a, uu: batched_consensus(a, uu))
    us = _time(f, ans, u)
    rows.append(("consensus_b64_n4", us, 64))

    # router phase A (vectorised Alg. 1 + budget scan)
    from repro.core import budget as bl
    from repro.core.router import RouterConfig, route
    cfg = RouterConfig.final()
    uu = jax.random.uniform(key, (256,))
    ss = jax.random.uniform(key, (256,))
    cost = jnp.full((256,), 0.001)
    bud = bl.init_budget(1.0)

    def r(uu, ss, cost):
        return route(uu, ss, cfg=cfg, budget=bud, wan_ok=True,
                     est_cloud_cost=cost).decision
    us = _time(jax.jit(r), uu, ss, cost)
    rows.append(("router_phaseA_b256", us, 256))

    # flash-attention oracle vs pallas-interpret (correct-by-construction)
    from repro.kernels.flash_attention.ref import flash_attention_ref
    q = jax.random.normal(key, (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(key, (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, 256, 2, 64), jnp.float32)
    us = _time(jax.jit(lambda a, b, c: flash_attention_ref(a, b, c)), q, k, v)
    rows.append(("flash_attention_ref_s256", us, 256))

    # ...and the Pallas kernel on the same shapes (compiled on TPU;
    # interpret-mode elsewhere, hence the low iteration count — the row
    # tracks kernel-vs-ref side by side so a TPU run shows the real win)
    from repro.kernels.flash_attention.ops import flash_attention
    on_tpu = jax.default_backend() == "tpu"
    us_k = _time(lambda a, b, c: flash_attention(a, b, c, force_pallas=True),
                 q, k, v, iters=20 if on_tpu else 2, warmup=3 if on_tpu else 1)
    rows.append(("flash_attention_kernel_s256", us_k, 256))

    # smoke-model decode step (serving inner loop)
    from repro import configs as C
    from repro.models import transformer as T
    cfg_m = C.get_smoke("smollm-135m")
    params = T.init_params(cfg_m, key)
    cache = jax.tree.map(jnp.asarray, T.init_cache(cfg_m, 4, 64))
    tok = jnp.zeros((4, 1), jnp.int32)
    idx = jnp.zeros((4,), jnp.int32)

    @jax.jit
    def dstep(params, tok, cache, idx):
        return T.decode_step(params, cfg_m, tok, cache, idx)
    us = _time(dstep, params, tok, cache, idx)
    rows.append(("decode_step_smoke_b4", us, 4))

    # two-phase serving runtime vs legacy stepwise absorption (B=4, S=32,
    # max_new=8 on the smollm smoke config) — the PR's headline speedup
    from repro.serving.engine import InferenceEngine
    from repro.serving.scheduler import Request
    eng = InferenceEngine("bench", cfg_m, params, max_len=64)
    rngp = np.random.RandomState(0)
    prompts = rngp.randint(7, cfg_m.vocab_size, size=(4, 32)).astype(np.int32)
    us_new = _time(lambda: eng.generate(prompts, 8)["tokens"], iters=10)
    us_old = _time(lambda: eng.generate_stepwise(prompts, 8)["tokens"],
                   iters=3, warmup=1)
    rows.append(("generate_prefill_scan_b4_s32_n8", us_new, 4))
    rows.append(("generate_stepwise_b4_s32_n8", us_old, 4))
    rows.append(("prefill_vs_stepwise", us_new, round(us_old / us_new, 2)))

    # batched streaming serve throughput (16 requests through 4 slots)
    def serve_once():
        reqs = [Request(rid=i, prompt=prompts[i % 4].tolist(), max_new=8)
                for i in range(16)]
        return eng.serve(reqs, n_slots=4, decode_chunk=8)
    us_serve = _time(lambda: np.zeros(len(serve_once())), iters=3, warmup=1)
    rows.append(("serve_16req_4slot_n8", us_serve,
                 round(16 * 8 / (us_serve / 1e6), 1)))  # tokens/s

    # graceful degradation under chaos (ISSUE 8): the same 16-request serve
    # with an injected fault schedule — two pool-famine admission rounds
    # (backpressure) plus a mid-decode slot failure (requeue, decode
    # progress lost).  Every request still finishes; the ratio row is the
    # relative degraded throughput (1.0 = zero overhead) and CI's chaos
    # smoke enforces its floor.
    from repro.serving.faults import FaultEvent, FaultPlan

    def serve_degraded():
        plan = FaultPlan([FaultEvent("pool", "famine", count=2),
                          FaultEvent("slot", "fail", count=1)])
        reqs = [Request(rid=i, prompt=prompts[i % 4].tolist(), max_new=8)
                for i in range(16)]
        fin = eng.serve(reqs, n_slots=4, decode_chunk=8, faults=plan)
        assert len(fin) == 16
        return fin
    us_deg = _time(lambda: np.zeros(len(serve_degraded())), iters=3, warmup=1)
    rows.append(("serve_chaos_16req_4slot_n8", us_deg,
                 round(16 * 8 / (us_deg / 1e6), 1)))  # tokens/s
    rows.append(("degraded_mode_throughput", us_deg,
                 round(us_serve / us_deg, 2)))

    # fused MoE serving vs stepwise (deepseek-style smoke: top-2 of 8
    # routed + 2 shared experts, B=4/S=32/max_new=8).  The capacity-aware
    # masked dispatch puts MoE configs on the same jitted-prefill +
    # scanned-decode path as dense configs — this row is the CI guard that
    # the fused path stays >= 3x the stepwise loop (ISSUE 3 acceptance).
    cfg_moe = C.get_smoke("deepseek-moe-16b")
    params_moe = T.init_params(cfg_moe, key)
    eng_moe = InferenceEngine("bench-moe", cfg_moe, params_moe, max_len=64)
    prompts_moe = rngp.randint(7, cfg_moe.vocab_size,
                               size=(4, 32)).astype(np.int32)
    us_moe = _time(lambda: eng_moe.generate(prompts_moe, 8)["tokens"],
                   iters=10)
    us_moe_sw = _time(lambda: eng_moe.generate_stepwise(
        prompts_moe, 8)["tokens"], iters=3, warmup=1)
    rows.append(("moe_generate_fused_b4_s32_n8", us_moe, 4))
    rows.append(("moe_generate_stepwise_b4_s32_n8", us_moe_sw, 4))
    rows.append(("moe_fused_vs_stepwise", us_moe,
                 round(us_moe_sw / us_moe, 2)))

    # multi-turn sessions: cold re-prefill of the whole conversation every
    # turn vs warm continuation prefill of only the new span (ISSUE 4
    # tentpole).  Long context + short turns is the regime multi-turn chat
    # lives in; the warm path's prefill cost is O(span), not O(history).
    ctx = rngp.randint(7, cfg_m.vocab_size, size=(4, 192)).astype(np.int32)
    turn = rngp.randint(7, cfg_m.vocab_size, size=(4, 8)).astype(np.int32)

    def _multiturn_cold():
        h = ctx
        r = eng.generate(h, 8)
        for _ in range(2):
            h = np.concatenate([h, r["tokens"], turn], axis=1)
            r = eng.generate(h, 8)
        return r["tokens"]

    def _multiturn_warm():
        r = eng.generate(ctx, 8, return_state=True)
        for _ in range(2):
            r = eng.generate(turn, 8, state=r["state"], return_state=True)
        return r["tokens"]
    us_cold = _time(_multiturn_cold, iters=5, warmup=1)
    us_warm = _time(_multiturn_warm, iters=5, warmup=1)
    rows.append(("multiturn3_cold_reprefill_s192", us_cold, 4))
    rows.append(("multiturn3_warm_continue_s192", us_warm, 4))
    rows.append(("multiturn_cold_vs_warm", us_warm,
                 round(us_cold / us_warm, 2)))

    # escalated swarm round: the probe member re-prefilling its own prompt
    # vs reusing the probe's answer + warm cache handle (the gateway path —
    # zero probe dispatches in the round).  Long prompts are the regime the
    # reuse targets (the probe prefill is the round's marginal cost).  CI
    # smoke enforces the floor.
    from repro.serving.swarm import SwarmExecutor
    peer = InferenceEngine("bench-peer", cfg_m, params, max_len=64)
    swarm = SwarmExecutor([eng, peer])
    probe_res = eng.generate(ctx, 8, return_state=True)

    def _round_reprefill():
        return swarm.collaborate(ctx, 8)["winner_tokens"]

    def _round_reuse():
        pre = {0: (probe_res["tokens"], probe_res["u"],
                   (probe_res["h_mean"], probe_res["v_mean"]))}
        return swarm.collaborate(ctx, 8, precomputed=pre,
                                 states={0: probe_res["state"]}
                                 )["winner_tokens"]
    us_re = _time(_round_reprefill, iters=5, warmup=1)
    us_ru = _time(_round_reuse, iters=5, warmup=1)
    rows.append(("swarm_round_reprefill_b4_s192_n8", us_re, 4))
    rows.append(("swarm_round_probe_reuse_b4_s192_n8", us_ru, 4))
    rows.append(("swarm_reprefill_vs_reuse", us_ru,
                 round(us_re / us_ru, 2)))

    # paged block-pool cache vs monolithic (ISSUE 5 tentpole).  Two rows:
    #   * paged_vs_monolithic_decode — pure decode-only extension over a
    #     warm session, paged tables vs monolithic buffers (the pool adds a
    #     per-step block gather; CI enforces <= 5% regression);
    #   * prefix_share_fanout — 8 sessions over one 448-token system
    #     prompt: COW block-table fan-out (ONE prefill) vs cold per-slot
    #     prefill of the same context (CI enforces the >= 2x floor; long
    #     shared prefixes are the regime prefix sharing targets — the
    #     per-slot prefill is the marginal cost it deletes).
    eng_pg = InferenceEngine("bench-paged", cfg_m, params, max_len=64,
                             paged=True, block_len=32, pool_blocks=512)
    st_mono = eng.absorb(ctx)
    st_pg = eng_pg.absorb(ctx)

    def _dec_mono():
        return eng.generate(None, 16, state=st_mono)["tokens"]

    def _dec_paged():
        return eng_pg.generate(None, 16, state=st_pg)["tokens"]
    us_dm = _time(_dec_mono, iters=20, warmup=3)
    us_dp = _time(_dec_paged, iters=20, warmup=3)
    rows.append(("decode_extend_monolithic_b4_n16", us_dm, 4))
    rows.append(("decode_extend_paged_b4_n16", us_dp, 4))
    rows.append(("paged_vs_monolithic_decode", us_dp,
                 round(us_dm / us_dp, 3)))

    # kernel-first vs gathered-view paged decode (ISSUE 6 tentpole).
    # eng_pg above runs the kernel-first default (in-place block-table
    # reads); the oracle engine gathers the slot-linear view per dispatch.
    # Bitwise-identical outputs — this row is purely the perf delta, and
    # benchmarks/decode_microbench.py breaks the same comparison down per
    # phase with bytes-moved and roofline fractions.
    eng_gv = InferenceEngine("bench-gather", cfg_m, params, max_len=64,
                             paged=True, block_len=32, pool_blocks=512,
                             attn_decode_impl="gather")
    st_gv = eng_gv.absorb(ctx)

    def _dec_gather():
        return eng_gv.generate(None, 16, state=st_gv)["tokens"]
    us_dg = _time(_dec_gather, iters=20, warmup=3)
    rows.append(("decode_extend_gather_b4_n16", us_dg, 4))
    rows.append(("kernel_vs_gather_paged_decode", us_dp,
                 round(us_dg / us_dp, 3)))

    sys_prompt = rngp.randint(7, cfg_m.vocab_size,
                              size=(1, 448)).astype(np.int32)

    def _fan_shared():
        st = eng_pg.absorb(sys_prompt)
        fan = eng_pg.fanout(st, 8)
        out = eng_pg.generate(None, 8, state=fan)["tokens"]
        eng_pg.release(fan); eng_pg.release(st)
        return out

    def _fan_cold():
        return eng.generate(np.tile(sys_prompt, (8, 1)), 8)["tokens"]
    us_fs = _time(_fan_shared, iters=5, warmup=1)
    us_fc = _time(_fan_cold, iters=5, warmup=1)
    rows.append(("prefix_fanout8_shared_blocks_s448", us_fs, 8))
    rows.append(("prefix_fanout8_cold_prefill_s448", us_fc, 8))
    rows.append(("prefix_share_fanout", us_fs, round(us_fc / us_fs, 2)))

    # mesh-sharded decode vs single-device (same B=4/S=32/max_new=8 smoke).
    # The serving mesh spans whatever devices are live: on a 1-device
    # container it is the degenerate (1, 1) mesh and the ratio measures the
    # sharded runtime's overhead (expect ~1.0x); on real multi-device
    # hardware it measures the actual data/tensor-parallel decode speedup.
    from repro.launch.mesh import serving_mesh
    mesh = serving_mesh(model_parallel=min(2, len(jax.devices())))
    eng_sh = InferenceEngine("bench-sharded", cfg_m, params, max_len=64,
                             mesh=mesh)
    us_sh = _time(lambda: eng_sh.generate(prompts, 8)["tokens"], iters=10)
    d, m = mesh.shape["data"], mesh.shape["model"]
    rows.append((f"generate_sharded_mesh{d}x{m}_b4_s32_n8", us_sh, 4))
    rows.append(("sharded_vs_single_decode", us_sh,
                 round(us_new / us_sh, 2)))

    # int8 error-feedback gradient compression
    from repro.training.compression import compress_with_feedback
    g = jax.random.normal(key, (1 << 20,))
    err = jnp.zeros_like(g)
    us = _time(jax.jit(compress_with_feedback), g, err)
    rows.append(("grad_compress_int8_1M", us, 1 << 20))

    return rows
