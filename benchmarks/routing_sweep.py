"""Routing-parameter sensitivity (paper Table I / Sec. V-C).

Replays one study run's logged probe signals (U, safety s, consensus S(a*))
through the REAL router for a grid of (tau_high, gamma), tracing the
cloud-usage / hard-accuracy-proxy frontier — the trade-off the paper tuned
by hand ("slightly more aggressive configuration", Sec. V-C).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import budget as B
from repro.core import router as R


def sweep_from_log(u: np.ndarray, s: np.ndarray, consensus: np.ndarray,
                   base: R.RouterConfig,
                   tau_high_grid=(0.5, 0.65, 0.8, 0.9, 0.95),
                   gamma_grid=(0.3, 0.6)) -> list[dict]:
    """tau_high_grid entries are U-quantiles; consensus NaN = no swarm round
    (treated as accepted)."""
    cons = np.where(np.isnan(consensus), 1.0, consensus)
    rows = []
    for q in tau_high_grid:
        for gamma in gamma_grid:
            cfg = dataclasses.replace(
                base, tau_high=float(np.quantile(u, q)), gamma=gamma)
            bud = B.init_budget(1.0)
            pa = R.route(jnp.asarray(u), jnp.asarray(s), cfg=cfg, budget=bud,
                         wan_ok=True,
                         est_cloud_cost=jnp.full(u.shape, 1e-4))
            pb = R.post_consensus(pa.decision, jnp.asarray(cons, np.float32),
                                  cfg=cfg, budget=pa.budget, wan_ok=True,
                                  est_cloud_cost=jnp.full(u.shape, 1e-4))
            dec = np.asarray(pb.decision)
            cloud = np.isin(dec, (R.CLOUD, R.CLOUD_SAFETY)).mean()
            rows.append({"tau_high_q": q, "gamma": gamma,
                         "cloud_usage": float(cloud),
                         "swarm_frac": float((dec == R.SWARM).mean()),
                         "local_frac": float((dec == R.LOCAL).mean())})
    return rows
