"""Per-phase decode microbench: kernel-first vs gathered-view paged serving.

Breaks one serving session into its three phases and times each under both
paged decode-attention impls (``attn_decode_impl`` in
``repro.serving.engine``):

* ``prefill``             — cold absorb of the context into pool blocks;
* ``continuation_insert`` — warm continuation prefill of a short span over
                            the live cache (multi-turn / swarm handoff);
* ``decode_step``         — per-token cost of a scanned decode dispatch
                            (the serving inner loop, reported per step).

Each row also carries an estimated bytes-moved figure and its HBM roofline
join against ``benchmarks/roofline.py``'s hardware model (time the bytes
would take at ``HBM_BW``, and that model time as a fraction of measured
wall-clock — meaningful on TPU; on CPU the fraction is only a shape-level
sanity signal).  Byte estimates count the dominant streams — parameter
bytes + the slot-linear attention KV view per decode step, measured from
the engine's actual cache shapes via ``jax.eval_shape`` — not every
activation.

The harness is also the enforcement point for the kernel-first claims:

* ``--check-hlo``       — assert (via ``repro.serving.hlo_probe``) that the
                          kernel-first decode executable does NOT
                          materialise the O(B * S) slot-linear KV view the
                          gathered-view executable provably carries;
* ``--assert-ratio X``  — fail unless kernel-first decode-step wall-clock
                          is <= X * gathered-view (CI floor: 1.0);
* ``--profile DIR``     — wrap one timed pass of each phase in a
                          ``jax.profiler`` trace for offline inspection;
* ``--compilation-cache-dir`` — engine-level persistent XLA cache, so a
                          re-run skips every already-seen jit.

Usage (CI smoke): PYTHONPATH=src python benchmarks/decode_microbench.py \
    --ctx 200 --steps 16 --check-hlo --assert-ratio 1.0
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")
sys.path.insert(0, "benchmarks")

from roofline import HBM_BW  # noqa: E402


def best_of(fn, iters: int, warmup: int = 3) -> float:
    """Min-of-N seconds per call (min, not mean: immune to load spikes,
    which is what a CI floor needs)."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def tree_bytes(tree) -> int:
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(tree)
               if hasattr(leaf, "dtype"))


def view_bytes(cfg, cache: dict, block_len: int) -> int:
    """Bytes of the slot-linear attention KV view for this cache — the
    per-decode-step attention read stream (both impls stream exactly these
    elements; the gather impl additionally materialises them per dispatch)."""
    from repro.models import transformer as T
    view_lens = {cache["table"].shape[1] * block_len}
    if cfg.window is not None:
        view_lens.add(cfg.window)
    gathered = jax.eval_shape(lambda c: T.paged_gather(cfg, c), cache)
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(gathered)
               if leaf.ndim >= 4 and leaf.shape[-3] in view_lens)


def stored_block_bytes(cfg, block_len: int, cache_quant) -> int:
    """Device bytes per pool block in the STORED representation (quantized
    payload + f32 scale sidecar), via eval_shape — no allocation."""
    from repro.models import transformer as T
    arrays = jax.eval_shape(lambda: T.init_block_pool(
        cfg, 8, block_len, 0, cache_quant=cache_quant))
    kv = sum(leaf.size * leaf.dtype.itemsize
             for sc in arrays for c in sc.values() if c.kv is not None
             for leaf in jax.tree_util.tree_leaves(c.kv))
    return kv // 8


def build_engine(args, impl: str, cache_quant=None, pool_blocks=None):
    from repro import configs as C
    from repro.core.uncertainty import UncertaintyConfig
    from repro.models import transformer as T
    from repro.serving.engine import InferenceEngine
    cfg = dataclasses.replace(C.get_smoke(args.arch), vocab_size=512)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(
        f"microbench-{impl}-{cache_quant or 'bf16'}", cfg, params,
        UncertaintyConfig(mode="distribution"), paged=True,
        block_len=args.block_len,
        pool_blocks=pool_blocks or args.pool_blocks,
        max_len=args.ctx + args.steps + 32, attn_decode_impl=impl,
        cache_quant=cache_quant,
        compilation_cache_dir=args.compilation_cache_dir)
    return eng


def bench_impl(args, impl: str, prompts, span,
               cache_quant=None) -> dict[str, dict]:
    eng = build_engine(args, impl, cache_quant=cache_quant)
    B = args.batch
    p_bytes = tree_bytes(eng.params)

    # warm state shared by the insert/decode phases
    st = eng.absorb(prompts)
    cache, _ = eng._paged_grown(st, st.offset + args.steps)
    v_bytes = view_bytes(eng.cfg, cache, eng.block_len)
    if cache_quant is not None:
        # the decode read stream is the STORED pool bytes (quantized
        # payload + scales), not the bf16 view eval_shape reports
        v_bytes = v_bytes * eng.pool.block_bytes \
            // stored_block_bytes(eng.cfg, eng.block_len, None)
    kv_write = v_bytes * args.ctx // cache["table"].shape[1] // eng.block_len

    def run_prefill():
        s = eng.absorb(prompts)
        eng.release(s)

    def run_insert():
        eng.generate(span, 1, state=st)

    def run_decode():
        eng.generate(None, args.steps, state=st)

    phases = {
        # cold prefill streams the params once and writes the context KV
        "prefill": (run_prefill, p_bytes + kv_write, 1),
        # continuation prefill: params once + one pass over the live view
        "continuation_insert": (run_insert, p_bytes + v_bytes, 1),
        # each decode step streams params + the live attention KV; the
        # gather impl ALSO materialises + scatters the view per dispatch
        "decode_step": (run_decode,
                        args.steps * (p_bytes + v_bytes)
                        + (3 * v_bytes if impl == "gather" else 0),
                        args.steps),
    }
    out = {}
    for name, (fn, nbytes, per) in phases.items():
        sec = best_of(fn, args.iters)
        if args.profile:
            with jax.profiler.trace(f"{args.profile}/{impl}_{name}"):
                fn()
        model_sec = nbytes / HBM_BW
        out[name] = {
            "ms": sec / per * 1e3,
            "est_mb": nbytes / per / 1e6,
            "hbm_model_ms": model_sec / per * 1e3,
            "hbm_frac": model_sec / sec if sec else 0.0,
        }
    return out


def session_density(args) -> dict[str, dict]:
    """Concurrent sessions per fixed pool byte budget, per cache format.

    The budget is what ``--pool-blocks`` bf16 blocks cost; each format
    gets ``budget // block_bytes`` blocks and admits ``ctx + steps``-long
    sessions through the REAL allocator until famine.  Runs on the FULL
    arch geometry (no weights needed — this is allocator arithmetic):
    the smoke configs' tiny head_dim inflates the f32 scale sidecar's
    share ~4x and would understate density.  int8 lands ~1.87x bf16 —
    the 2x payload saving minus the scale sidecar (4/head_dim of the
    payload) and the shared int32 pos rows; fp8 is byte-identical to
    int8 — its win is range, not density."""
    from repro import configs as C
    from repro.serving.cache_manager import CachePool, PoolExhaustedError
    cfg = C.get_config(args.arch)
    nb_sess = -(-(args.ctx + args.steps) // args.block_len)
    budget = args.pool_blocks * stored_block_bytes(cfg, args.block_len, None)
    out = {}
    for quant in (None, "int8", "fp8"):
        bb = stored_block_bytes(cfg, args.block_len, quant)
        n_blocks = max(budget // bb, nb_sess)
        # rows (recurrent state) sit outside the KV block budget; size
        # them off the session bound so block famine is the binding limit
        pool = CachePool(cfg, args.block_len, n_blocks,
                         n_rows=n_blocks // nb_sess + 1, cache_quant=quant)
        n = 0
        try:
            while True:
                pool.alloc(1, nb_sess)
                n += 1
        except PoolExhaustedError:
            pass
        out[quant or "bf16"] = {
            "sessions": n, "blocks": pool.n_blocks,
            "block_kib": pool.block_bytes / 1024,
            "pool_mib": pool.pool_bytes / 2**20,
        }
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=200,
                    help="live context length before the timed phases")
    ap.add_argument("--span", type=int, default=8,
                    help="continuation-insert span length")
    ap.add_argument("--steps", type=int, default=16,
                    help="decode steps per dispatch")
    ap.add_argument("--block-len", type=int, default=32)
    ap.add_argument("--pool-blocks", type=int, default=512)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="write a jax.profiler trace per phase under DIR")
    ap.add_argument("--check-hlo", action="store_true",
                    help="assert the kernel-first decode executable drops "
                         "the slot-linear KV view")
    ap.add_argument("--assert-ratio", type=float, default=None, metavar="X",
                    help="fail unless kernel decode_step <= X * gather")
    ap.add_argument("--quant", choices=("int8", "fp8"), default=None,
                    help="also bench a cache_quant engine (kernel impl) "
                         "and the per-format session_density table")
    ap.add_argument("--assert-density", type=float, default=None,
                    metavar="X", help="fail unless int8 fits >= X times "
                                      "the bf16 sessions at fixed pool "
                                      "bytes (CI floor: 1.8)")
    ap.add_argument("--assert-quant-decode", type=float, default=None,
                    metavar="X", help="fail unless the quantized decode "
                                      "step is <= X * the bf16 one "
                                      "(CI ceiling: 1.15 on CPU)")
    ap.add_argument("--compilation-cache-dir", default=None)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    prompts = rng.integers(7, 500, size=(args.batch, args.ctx)).astype(
        np.int32)
    span = rng.integers(7, 500, size=(args.batch, args.span)).astype(np.int32)

    results = {impl: bench_impl(args, impl, prompts, span)
               for impl in ("kernel", "gather")}
    impls = [("kernel", "kernel"), ("gather", "gather")]
    if args.quant:
        results[f"kernel@{args.quant}"] = bench_impl(
            args, "kernel", prompts, span, cache_quant=args.quant)
        impls.append((f"kernel@{args.quant}", f"kernel@{args.quant}"))

    hdr = (f"{'phase':<22}{'impl':<14}{'ms/call':>10}{'est MB':>10}"
           f"{'HBM-model ms':>14}{'frac':>8}")
    print(hdr)
    print("-" * len(hdr))
    for name in ("prefill", "continuation_insert", "decode_step"):
        for key, label in impls:
            r = results[key][name]
            print(f"{name:<22}{label:<14}{r['ms']:>10.3f}"
                  f"{r['est_mb']:>10.2f}"
                  f"{r['hbm_model_ms']:>14.4f}{r['hbm_frac']:>8.3f}")
    ratio = (results["kernel"]["decode_step"]["ms"]
             / results["gather"]["decode_step"]["ms"])
    print(f"\nkernel_vs_gather_paged_decode: {ratio:.3f} "
          f"(kernel decode-step / gather decode-step; <1 = kernel faster)")

    failed = False
    if args.quant:
        dens = session_density(args)
        print(f"\n{'session_density':<22}{'format':<14}{'sessions':>10}"
              f"{'blocks':>10}{'block KiB':>12}{'pool MiB':>10}")
        for fmt, d in dens.items():
            print(f"{'':<22}{fmt:<14}{d['sessions']:>10}{d['blocks']:>10}"
                  f"{d['block_kib']:>12.1f}{d['pool_mib']:>10.1f}")
        drat = dens["int8"]["sessions"] / dens["bf16"]["sessions"]
        qrat = (results[f"kernel@{args.quant}"]["decode_step"]["ms"]
                / results["kernel"]["decode_step"]["ms"])
        print(f"session_density_int8_vs_bf16: {drat:.3f}x at fixed pool "
              "bytes (2x payload minus the f32 scale sidecar + pos rows)")
        print(f"quant_decode_step_vs_bf16: {qrat:.3f}x wall-clock")
        if args.assert_density is not None:
            ok = drat >= args.assert_density
            print(f"density_floor: {'OK' if ok else 'FAIL'} "
                  f"({drat:.3f} vs >= {args.assert_density})")
            failed |= not ok
        if args.assert_quant_decode is not None:
            ok = qrat <= args.assert_quant_decode
            print(f"quant_decode_ceiling: {'OK' if ok else 'FAIL'} "
                  f"({qrat:.3f} vs <= {args.assert_quant_decode})")
            failed |= not ok
    if args.check_hlo:
        from repro.serving.hlo_probe import assert_no_slot_linear_kv
        try:
            acct = assert_no_slot_linear_kv(
                build_engine(args, "gather"), build_engine(args, "kernel"),
                prompts[:, -16:], steps=4)
            print(f"hlo_check: OK — gather carries {acct['in_gather_hlo']}, "
                  f"kernel-first drops all of it")
        except AssertionError as e:
            print(f"hlo_check: FAIL — {e}")
            failed = True
    if args.assert_ratio is not None:
        ok = ratio <= args.assert_ratio
        print(f"ratio_floor: {'OK' if ok else 'FAIL'} "
              f"({ratio:.3f} vs <= {args.assert_ratio})")
        failed |= not ok
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
