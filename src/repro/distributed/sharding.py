"""Logical-axis sharding rules for the SWARM-LLM framework.

Every parameter / activation dimension is tagged with a *logical* axis name;
rules map logical names to (tuples of) physical mesh axes.  Specs are built
with divisibility checking: a logical axis only shards over a physical axis
set when the dimension size divides the product of those axes' sizes,
otherwise it falls back down a chain of alternatives (ultimately replicated).

This mirrors the MaxText/Flax `logical_axis_rules` pattern but is pure JAX:
params are plain pytrees and the model definition produces a parallel pytree
of logical-axis tuples (see ``models/*.py: param_axes``).

Public entry points (consumed by training/train.py, launch/dryrun.py and
the mesh-sharded serving engine — see docs/SHARDING.md):

* ``spec_for(shape, logical, mesh, rules) -> PartitionSpec`` — one array.
* ``tree_specs / tree_shardings`` — map ``spec_for`` over parallel
  (shapes, logical-axes) pytrees; ``tree_shardings`` wraps the specs in
  ``NamedSharding`` for jit in/out shardings and ``device_put``.
* ``constrain(x, logical, mesh, rules)`` — ``with_sharding_constraint``
  by logical names; a no-op when ``mesh`` is None, which is how the
  serving/runtime code stays bit-identical off-mesh.
* ``RULE_SETS``: ``default`` (training FSDP x TP), ``sp`` (sequence-
  parallel prefill), ``serve`` (weights replicated over 'data', pure TP —
  decode never re-gathers FSDP shards).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Each logical axis maps to a *preference chain*: the first physical-axis
# tuple whose size divides the dimension wins; `None` (replicate) always
# terminates the chain implicitly.
#
# Physical axes: "pod" (cross-pod DCN/ICI), "data" (FSDP/batch), "model" (TP).

MeshAxes = tuple[str, ...]
Chain = tuple[MeshAxes, ...]


def _chain(*alts: Sequence[str] | str | None) -> Chain:
    out = []
    for a in alts:
        if a is None:
            continue
        if isinstance(a, str):
            out.append((a,))
        else:
            out.append(tuple(a))
    return tuple(out)


# Parameter logical axes.
PARAM_RULES: dict[str, Chain] = {
    "layers": _chain(),                          # scan-stacked layer dim: never sharded
    "vocab": _chain("model"),                    # embedding / lm-head vocab dim (TP)
    "embed": _chain(("pod", "data"), "data"),    # d_model dim of params (FSDP)
    "heads": _chain("model"),                    # attention q heads (TP)
    "kv_heads": _chain("model"),                 # attention kv heads (TP when divisible)
    "head_dim": _chain(),                        # per-head dim
    "ffn": _chain("model"),                      # MLP hidden (TP)
    "experts": _chain("model"),                  # MoE experts (EP)
    "expert_ffn": _chain(),                      # per-expert hidden
    "ssm_inner": _chain("model"),                # mamba d_inner / rg-lru width
    "ssm_state": _chain(),                       # SSD state dim
    "conv_width": _chain(),
    "norm": _chain(),
    "bias_ffn": _chain("model"),
    "bias_heads": _chain("model"),
}

# Activation logical axes.
ACT_RULES: dict[str, Chain] = {
    "act_batch": _chain(("pod", "data"), "data"),
    "act_seq": _chain(),                         # sequence (SP variant remaps this)
    "act_embed": _chain(),
    "act_heads": _chain("model"),
    "act_kv_heads": _chain("model"),
    "act_head_dim": _chain(),
    "act_vocab": _chain("model"),                # logits vocab dim
    "act_ffn": _chain("model"),
    "act_experts": _chain("model"),
    "act_expert_cap": _chain(),
    # serving-MoE dispatch tensors (docs/SHARDING.md "capacity buffer" rows):
    # the per-position prefill buffer's group dim is the sequence — pinned
    # unsharded (like act_seq) and the expert dim kept OFF 'model', so the
    # 3-index dispatch scatter stays a per-group scatter instead of SPMD's
    # dense select-update rewrite (see moe.moe_block's sharding note).
    "act_moe_group": _chain(),
    # decode's gathered top-k expert weights: batch('data') x replicated k/Fe
    # — each data shard gathers only its own tokens' k weight rows from the
    # 'model'-sharded resident experts.
    "act_topk": _chain(),
    "act_expert_ffn": _chain(),
    "act_ssm_inner": _chain("model"),
    "act_state": _chain(),
    "act_kv_seq": _chain("model"),               # KV-cache seq: fallback TP
    # dim when kv_heads doesn't divide the model axis (Pope et al.-style
    # sequence-sharded cache; softmax partials all-reduce over 'model')
    # paged cache pool (docs/SHARDING.md "paged pool & block tables"): the
    # block/state-row dim of the per-layer pools shards over 'data' like a
    # batch dim — the allocator hands out contiguous slot-major runs, so a
    # slot's blocks land on few 'data' shards; tables/row-ids ride with
    # act_batch and the block_len dim inside a block stays unsharded.
    "act_pool": _chain(("pod", "data"), "data"),
    # quantized-pool scale sidecar (cache_quant engines): the per-row f32
    # scale leaves (n_blocks, L, K) shard exactly like their pool — block
    # dim over 'data' — so a block and its scales always land on the same
    # shard and the fused-dequant read never crosses devices for a scale.
    "act_pool_scale": _chain(("pod", "data"), "data"),
}

# Dims with lower numbers claim mesh axes first (a KV cache lists seq before
# heads in layout order, but heads should win the 'model' axis when it can).
AXIS_PRIORITY = {
    "act_kv_heads": 0, "act_heads": 0, "heads": 0, "kv_heads": 0,
    "ffn": 0, "experts": 0, "vocab": 0, "act_vocab": 0, "act_ffn": 0,
    "act_experts": 0, "ssm_inner": 0, "act_ssm_inner": 0,
    "act_batch": 0, "act_pool": 0, "act_pool_scale": 0, "embed": 1,
    "act_kv_seq": 2,
}


@dataclasses.dataclass(frozen=True, eq=False)
class ShardingRules:
    """A rule set = param rules + activation rules (both overridable).

    Hashable by rule content so a rule set can ride through ``jax.jit`` as a
    static argument (the serving engine closes its jitted prefill/decode
    over (mesh, rules) this way).
    """

    param_rules: Mapping[str, Chain] = dataclasses.field(
        default_factory=lambda: dict(PARAM_RULES))
    act_rules: Mapping[str, Chain] = dataclasses.field(
        default_factory=lambda: dict(ACT_RULES))

    def _frozen(self) -> tuple:
        return (tuple(sorted(self.param_rules.items())),
                tuple(sorted(self.act_rules.items())))

    def __hash__(self) -> int:
        return hash(self._frozen())

    def __eq__(self, other: object) -> bool:
        return (isinstance(other, ShardingRules)
                and self._frozen() == other._frozen())

    def with_overrides(self, *, params: Mapping[str, Chain] | None = None,
                       acts: Mapping[str, Chain] | None = None) -> "ShardingRules":
        p = dict(self.param_rules)
        a = dict(self.act_rules)
        if params:
            p.update(params)
        if acts:
            a.update(acts)
        return ShardingRules(param_rules=p, act_rules=a)


DEFAULT_RULES = ShardingRules()

# Sequence-parallel variant: long prefill shards seq over the model axis for
# everything outside attention (norms / MLP); attention re-gathers.
SP_RULES = DEFAULT_RULES.with_overrides(acts={"act_seq": _chain("model")})

# Decode-serving variant (weights stay put, activations move — Pope et al.).
# Under DEFAULT_RULES a decode step re-all-gathers the FSDP ('data'-dim)
# weight shards every token (measured: 24 GB/device/step -> 0.49 s
# collective term on command-r decode_32k).  Serving has no optimizer state,
# so bf16 weights are replicated over 'data' (pure TP over 'model'): the
# only per-step collectives are activation-sized all-reduces.  Batch stays
# data-sharded; the KV cache is (batch x seq|heads) 2-D sharded.
SERVE_RULES = DEFAULT_RULES.with_overrides(
    params={"embed": _chain(), "vocab": _chain("model")},
)

RULE_SETS = {"default": DEFAULT_RULES, "sp": SP_RULES, "serve": SERVE_RULES}


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def spec_for(shape: Sequence[int], logical: Sequence[str | None],
             mesh: Mesh, rules: Mapping[str, Chain]) -> P:
    """Build a PartitionSpec for `shape` given per-dim logical names.

    Divisibility-aware: each logical axis walks its preference chain and
    takes the first physical-axis tuple (a) whose axes are all present in
    the mesh, (b) not already used by an earlier dim, and (c) whose total
    size divides the dim.
    """
    assert len(shape) == len(logical), (shape, logical)
    used: set[str] = set()
    parts: list[Any] = [None] * len(shape)
    order = sorted(range(len(shape)),
                   key=lambda d: AXIS_PRIORITY.get(logical[d], 1))
    for d in order:
        dim, name = shape[d], logical[d]
        if name is None:
            continue
        chain = rules.get(name, ())
        for axes in chain:
            if any(a not in mesh.shape for a in axes):
                continue
            if any(a in used for a in axes):
                continue
            if dim % _axis_size(mesh, axes) != 0:
                continue
            parts[d] = axes if len(axes) > 1 else axes[0]
            used.update(axes)
            break
    # Trim trailing Nones for cleanliness.
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_specs(shapes: Any, axes_tree: Any, mesh: Mesh,
               rules: Mapping[str, Chain]) -> Any:
    """Map `spec_for` over parallel pytrees of shapes and logical-axis tuples.

    `shapes` leaves are either jax.ShapeDtypeStruct / arrays (have .shape) or
    raw tuples. `axes_tree` leaves are tuples of logical names (or None).
    """
    def one(shape_leaf, ax):
        if shape_leaf is None or ax is None:
            return None
        shape = getattr(shape_leaf, "shape", shape_leaf)
        return spec_for(shape, ax, mesh, rules)

    return jax.tree.map(one, shapes, axes_tree,
                        is_leaf=lambda x: x is None or (
                            isinstance(x, tuple) and all(
                                isinstance(e, (str, type(None))) for e in x)))


def tree_shardings(shapes: Any, axes_tree: Any, mesh: Mesh,
                   rules: ShardingRules | None = None) -> Any:
    rules = rules or DEFAULT_RULES
    specs = tree_specs(shapes, axes_tree, mesh, rules.param_rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def constrain(x: jax.Array, logical: Sequence[str | None], mesh: Mesh | None,
              rules: ShardingRules | None = None) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op off-mesh."""
    if mesh is None or mesh.empty:
        return x
    rules = rules or DEFAULT_RULES
    spec = spec_for(x.shape, logical, mesh, rules.act_rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
