"""Paged block-pool cache manager: block tables, refcounts, COW sharing.

The monolithic serving caches (``transformer.init_cache``) back every
request with a contiguous ``(B, max_len, ...)`` buffer per layer: growing a
session means ``grow_cache``'s whole-buffer copy, and a system prompt
absorbed once is re-materialised per slot.  This module replaces that
representation for engines constructed with ``paged=True``:

* **Block pool** — every attention layer's K/V lives in a fixed pool of
  fixed-size blocks ``(n_blocks, block_len, kv_heads, head_dim)`` (positions
  pooled alongside as ``(n_blocks, block_len)``); recurrent/conv state rows
  (RG-LRU, SSD) are pooled as ``(n_rows, ...)`` rows.  The pool arrays are
  built by ``transformer.init_block_pool`` and owned by one
  :class:`CachePool` per engine.
* **Block tables** — a slot/session references cache storage through a
  ``(B, nb)`` int32 table of pool block ids plus a ``(B,)`` state-row id.
  The jitted serving phases gather a slot-linear view of the table
  (``attention.paged_view``) and scatter writes through it, so the device
  code never sees anything but the table and the pool.
* **Growth without copy** — extending a session appends freshly reset
  blocks to its table (O(new blocks)); nothing existing is copied.  The
  monolithic path's ``grow_cache`` full-buffer copy is counted by the
  engine's ``grow_copy`` counter and stays at zero for paged engines.
* **Copy-on-write prefix sharing** — fanning a session out to N slots
  copies its *table*, bumping per-block refcounts; blocks at or past the
  next write position are COW-copied per slot (at most the one partially
  filled tail block), everything earlier is shared read-only.  A shared
  block (refcount > 1) is never in any dispatch's write range — that is the
  allocator's core invariant — so one absorbed system prompt serves N
  sessions with exactly one prefill.
* **Eviction / TTL** — session handles are registered with the pool;
  ``evict_idle(ttl_s)`` releases handles idle past the TTL and returns
  their blocks.  Reusing an evicted handle raises.

Everything here is host-side bookkeeping (numpy tables, free lists,
refcounts); the only device work is block reset/copy scatters, each O(the
blocks touched), dispatched through small cached jits.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as sh
from repro.models import transformer as T
from repro.serving.faults import PoolExhaustedError


class EvictedSessionError(ValueError):
    """A paged session handle was used after release / TTL eviction."""


class QuantMismatchError(ValueError):
    """A session checkpoint's cache representation does not match the
    restoring engine's (``cache_quant`` differs, or a quantized paged
    checkpoint meets a monolithic engine).  Raised instead of silently
    changing the session's numeric precision mid-conversation."""


@dataclasses.dataclass
class PagedHandle:
    """A session's view into a :class:`CachePool`.

    ``tables`` holds only the *covered* blocks (positions written so far,
    rounded up to a block); the engine re-extends to the dispatch width —
    with freshly reset blocks, which is exactly the content the monolithic
    cache has there — before running, so trimming is invisible to numerics.
    ``epoch`` is the pool epoch at creation (bumped by every eviction
    sweep); together with ``sid`` it makes stale-handle reuse loud.
    """

    tables: np.ndarray          # (B, nb_covered) int32 pool block ids
    rows: np.ndarray            # (B,) int32 state-row ids
    sid: int                    # session id in the owning pool
    epoch: int                  # pool epoch at creation

    @property
    def batch(self) -> int:
        return int(self.tables.shape[0])


class CachePool:
    """Fixed pool of KV blocks + recurrent-state rows with a host allocator.

    One per paged :class:`~repro.serving.engine.InferenceEngine`.  Owns the
    device pool arrays (``self.arrays``, the ``layers`` entry of the paged
    cache pytree) and swaps them for each dispatch's output via
    :meth:`commit` — sessions hold block *tables*, never arrays, so the swap
    is invisible to them.

    Allocation prefers the lowest-numbered free blocks (a heap), so a
    slot's run stays as contiguous as the churn allows — with the pool's
    block dim sharded over the mesh 'data' axis (``act_pool`` rule,
    docs/SHARDING.md) contiguous slot-major runs keep a slot's blocks on
    few shards.
    """

    def __init__(self, cfg, block_len: int, n_blocks: int, n_rows: int, *,
                 cache_quant: str | None = None,
                 mesh=None, rules=None, clock=time.monotonic):
        from repro.models import quant as Q
        self.cfg = cfg
        self.block_len = int(block_len)
        self.n_blocks = int(n_blocks)
        self.n_rows = int(n_rows)
        self.cache_quant = Q.check_quant(cache_quant)
        self.mesh, self.rules = mesh, rules
        self._clock = clock
        # local-attention layers view the FIRST ring_blocks table entries
        # as a ring buffer (slot = position % window): once decode wraps,
        # ANY of them is in the write range regardless of the linear write
        # position, so COW must treat them as writable when shared (a
        # purely linear write-range check would write through shared ring
        # blocks and corrupt sibling sessions)
        self.ring_blocks = 0
        if cfg.window is not None and any(
                m == "attn_local" for m, _ in cfg.layer_plan()):
            self.ring_blocks = max(cfg.window // block_len, 1)
        arrays = T.init_block_pool(cfg, n_blocks, block_len, n_rows,
                                   cache_quant=cache_quant)
        if mesh is not None:
            rules = rules or sh.SERVE_RULES
            specs = sh.tree_specs(
                arrays,
                T.paged_cache_axes(
                    cfg, quantized=cache_quant is not None)["layers"],
                mesh, rules.act_rules)
            arrays = jax.device_put(arrays, jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s), specs))
        else:
            arrays = jax.tree.map(jnp.asarray, arrays)
        self.arrays = arrays
        # host metadata
        import heapq
        self._heapq = heapq
        self._free = list(range(n_blocks)); heapq.heapify(self._free)
        self._free_rows = list(range(n_rows)); heapq.heapify(self._free_rows)
        self.ref = np.zeros((n_blocks,), np.int64)
        self.row_ref = np.zeros((n_rows,), np.int64)
        self.epoch = 0
        self._sessions: dict[int, dict] = {}
        self._next_sid = 0
        self.counters = {"blocks_alloc": 0, "blocks_freed": 0,
                         "blocks_reset": 0, "cow_copies": 0,
                         "row_copies": 0, "evictions": 0, "high_water": 0}
        # pool maintenance ops donate the pool arrays: every call site is
        # self.arrays = self._op(self.arrays, ...), so the input buffers
        # are dead the moment the op returns and the scatter can run in
        # place instead of copying the pool
        cfg_ = cfg
        self._reset_blocks = jax.jit(
            lambda layers, ids: T.reset_blocks(cfg_, layers, ids),
            donate_argnums=(0,))
        self._reset_rows = jax.jit(
            lambda layers, ids: T.reset_rows(cfg_, layers, ids),
            donate_argnums=(0,))
        self._copy_blocks = jax.jit(
            lambda layers, src, dst: T.copy_blocks(cfg_, layers, src, dst),
            donate_argnums=(0,))
        self._copy_rows = jax.jit(
            lambda layers, src, dst: T.copy_rows(cfg_, layers, src, dst),
            donate_argnums=(0,))

    # ------------------------------------------------------------------
    # raw block / row allocation
    # ------------------------------------------------------------------

    @property
    def blocks_in_use(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def block_bytes(self) -> int:
        """Device bytes per pool block across all attention layers, in the
        pool's STORED representation — quantized payload plus f32 scale
        sidecar for ``cache_quant`` pools, so famine messages and the
        session-density benchmark report real headroom, not the bf16
        equivalent."""
        kv = sum(leaf.nbytes
                 for sc in self.arrays for c in sc.values()
                 if c.kv is not None for leaf in c.kv if leaf is not None)
        return kv // self.n_blocks

    @property
    def pool_bytes(self) -> int:
        """Total device bytes of the KV block pool (stored representation)."""
        return self.block_bytes * self.n_blocks

    @property
    def quant_label(self) -> str:
        return self.cache_quant or "bf16"

    def _famine_detail(self) -> str:
        return (f"{self.quant_label} blocks of "
                f"{self.block_bytes / 1024:.1f} KiB, pool "
                f"{self.pool_bytes / 2**20:.1f} MiB")

    def can_alloc(self, n_blocks: int, n_rows: int = 0) -> bool:
        return (len(self._free) >= n_blocks
                and len(self._free_rows) >= n_rows)

    def alloc_blocks(self, n: int, *, reset: bool = True) -> np.ndarray:
        """Take ``n`` free blocks (refcount 1).  ``reset=True`` zeroes their
        K/V and sets pos = -1 — O(n), the paged replacement for the
        monolithic path's O(max_len) ``grow_cache`` copy."""
        if len(self._free) < n:
            raise PoolExhaustedError(
                f"cache pool exhausted: need {n} blocks, "
                f"{len(self._free)}/{self.n_blocks} free "
                f"({self._famine_detail()}) — grow pool_blocks, "
                "release sessions, or enable TTL eviction")
        ids = np.array([self._heapq.heappop(self._free) for _ in range(n)],
                       np.int32)
        self.ref[ids] = 1
        self.counters["blocks_alloc"] += n
        self.counters["high_water"] = max(self.counters["high_water"],
                                          self.blocks_in_use)
        if reset and n:
            self.arrays = self._reset_blocks(self.arrays, jnp.asarray(ids))
            self.counters["blocks_reset"] += n
        return ids

    def free_blocks(self, ids: np.ndarray) -> None:
        """Drop one reference per id; blocks at refcount 0 return to the
        free list (repeats in ``ids`` drop that many references)."""
        for i in np.asarray(ids, np.int64).ravel():
            self.ref[i] -= 1
            assert self.ref[i] >= 0, f"double free of block {i}"
            if self.ref[i] == 0:
                self._heapq.heappush(self._free, int(i))
                self.counters["blocks_freed"] += 1

    def share_blocks(self, ids: np.ndarray) -> None:
        np.add.at(self.ref, np.asarray(ids, np.int64).ravel(), 1)

    def alloc_rows(self, n: int) -> np.ndarray:
        if len(self._free_rows) < n:
            raise PoolExhaustedError(
                f"cache pool exhausted: need {n} state rows, "
                f"{len(self._free_rows)}/{self.n_rows} free")
        ids = np.array([self._heapq.heappop(self._free_rows)
                        for _ in range(n)], np.int32)
        self.row_ref[ids] = 1
        if n:
            self.arrays = self._reset_rows(self.arrays, jnp.asarray(ids))
        return ids

    def free_rows(self, ids: np.ndarray) -> None:
        for i in np.asarray(ids, np.int64).ravel():
            self.row_ref[i] -= 1
            assert self.row_ref[i] >= 0, f"double free of row {i}"
            if self.row_ref[i] == 0:
                self._heapq.heappush(self._free_rows, int(i))

    def commit(self, layers: Any) -> None:
        """Swap in the pool arrays a dispatch returned.  Blocks not in the
        dispatch's write range are bit-identical in the new arrays, so
        every other session's table stays valid."""
        self.arrays = layers

    # ------------------------------------------------------------------
    # session handles
    # ------------------------------------------------------------------

    def register(self, tables: np.ndarray, rows: np.ndarray) -> PagedHandle:
        sid = self._next_sid
        self._next_sid += 1
        h = PagedHandle(np.asarray(tables, np.int32).copy(),
                        np.asarray(rows, np.int32).copy(), sid, self.epoch)
        self._sessions[sid] = {"handle": h, "last_used": self._clock()}
        return h

    def alloc(self, batch: int, nb: int) -> PagedHandle:
        """A fresh session: ``batch`` runs of ``nb`` reset blocks + zeroed
        state rows."""
        tables = self.alloc_blocks(batch * nb).reshape(batch, nb)
        rows = self.alloc_rows(batch)
        return self.register(tables, rows)

    def check(self, handle: PagedHandle) -> None:
        """Validate + touch a handle; raises on released/evicted ones."""
        meta = self._sessions.get(handle.sid)
        if meta is None or meta["handle"] is not handle:
            raise EvictedSessionError(
                f"paged session {handle.sid} (pool epoch {handle.epoch}) was "
                f"released or TTL-evicted (pool epoch now {self.epoch}); its "
                "blocks are recycled — re-absorb the context")
        meta["last_used"] = self._clock()

    def release(self, handle: PagedHandle) -> None:
        """Return a session's blocks/rows to the pool and invalidate it."""
        self.check(handle)
        del self._sessions[handle.sid]
        self.free_blocks(handle.tables)
        self.free_rows(handle.rows)

    def evict_idle(self, ttl_s: float, now: float | None = None,
                   exclude=()) -> int:
        """Release every registered session idle for more than ``ttl_s``
        seconds; bumps the pool epoch when anything was evicted.
        ``exclude`` (session ids) protects handles a caller still intends
        to use — serve() passes the handles its queued warm requests
        reference, so famine recovery cannot evict its own admissions."""
        now = self._clock() if now is None else now
        victims = [sid for sid, m in self._sessions.items()
                   if now - m["last_used"] > ttl_s and sid not in exclude]
        for sid in victims:
            h = self._sessions.pop(sid)["handle"]
            self.free_blocks(h.tables)
            self.free_rows(h.rows)
            self.counters["evictions"] += 1
        if victims:
            self.epoch += 1
        return len(victims)

    @property
    def live_sessions(self) -> int:
        return len(self._sessions)

    # ------------------------------------------------------------------
    # COW table operations
    # ------------------------------------------------------------------

    def _cow_and_grow(self, run: np.ndarray, nb: int, write_pos: int,
                      cow_src: list, cow_dst: list,
                      fresh: list) -> np.ndarray:
        """One table row made safe to write from ``write_pos`` and extended
        to ``nb`` blocks.  Shared blocks in the write range — linear blocks
        at or past the write position, plus the first ``ring_blocks``
        entries any local-attention layer may wrap into — are queued for
        COW copy; missing tail blocks are queued for fresh allocation.
        Device copies/resets are batched by the caller."""
        row = list(int(b) for b in run)
        wb = write_pos // self.block_len
        for j in range(len(row)):
            if j < min(wb, len(row)) and j >= self.ring_blocks:
                continue                       # read-only prefix: share
            if self.ref[row[j]] > 1:
                nbk = int(self.alloc_blocks(1, reset=False)[0])
                cow_src.append(row[j]); cow_dst.append(nbk)
                self.free_blocks(np.array([row[j]]))   # drop our shared ref
                row[j] = nbk
        if len(row) < nb:
            need = nb - len(row)
            new = self.alloc_blocks(need, reset=False)
            fresh.extend(int(b) for b in new)
            row.extend(int(b) for b in new)
        return np.asarray(row[:nb], np.int32)

    def _flush(self, cow_src: list, cow_dst: list, fresh: list) -> None:
        if cow_src:
            self.arrays = self._copy_blocks(
                self.arrays, jnp.asarray(np.asarray(cow_src, np.int32)),
                jnp.asarray(np.asarray(cow_dst, np.int32)))
            self.counters["cow_copies"] += len(cow_src)
        if fresh:
            self.arrays = self._reset_blocks(
                self.arrays, jnp.asarray(np.asarray(fresh, np.int32)))
            self.counters["blocks_reset"] += len(fresh)

    def extend(self, handle: PagedHandle, nb: int,
               write_pos: np.ndarray) -> np.ndarray:
        """Grow ``handle`` (in place) to ``nb`` blocks per row, COW-copying
        any shared block in the per-row write range.  Returns the new
        ``(B, nb)`` tables — appended blocks are freshly reset, never a
        whole-cache copy."""
        self.check(handle)
        write_pos = np.asarray(write_pos, np.int64).reshape(-1)
        cow_src, cow_dst, fresh = [], [], []
        rows = [self._cow_and_grow(handle.tables[b], nb, int(write_pos[b]),
                                   cow_src, cow_dst, fresh)
                for b in range(handle.batch)]
        self._flush(cow_src, cow_dst, fresh)
        handle.tables = np.stack(rows, axis=0)
        return handle.tables

    def select(self, handle: PagedHandle, idx) -> PagedHandle:
        """Fork rows ``idx`` of a session into a NEW handle: block tables
        are copied by reference (refcount++ — repeated indices fan one row
        out to many), state rows are copied on device (they are rewritten
        every decode step, so they cannot be shared).  O(table + state
        rows), never O(cache)."""
        self.check(handle)
        idx = np.asarray(idx, np.int64).reshape(-1)
        tables = handle.tables[idx]
        self.share_blocks(tables)
        rows = self.alloc_rows(len(idx))
        self.arrays = self._copy_rows(
            self.arrays, jnp.asarray(handle.rows[idx]), jnp.asarray(rows))
        self.counters["row_copies"] += len(idx)
        return self.register(tables, rows)

    def admit_row(self, handle: PagedHandle, nb: int, write_pos: int,
                  row_index: int = 0) -> tuple[np.ndarray, int]:
        """Serve-slot admission off a (possibly shared) session handle: the
        slot gets its own table row — prefix blocks shared by reference,
        write-range blocks COW-copied, tail freshly allocated — plus a
        device copy of the state row.  The handle itself is untouched, so
        N requests can admit off one absorbed prefix."""
        self.check(handle)
        self.share_blocks(handle.tables[row_index])   # our working reference
        cow_src, cow_dst, fresh = [], [], []
        run = self._cow_and_grow(handle.tables[row_index], nb, write_pos,
                                 cow_src, cow_dst, fresh)
        self._flush(cow_src, cow_dst, fresh)
        row = int(self.alloc_rows(1)[0])
        self.arrays = self._copy_rows(
            self.arrays, jnp.asarray(handle.rows[row_index:row_index + 1]),
            jnp.asarray(np.array([row], np.int32)))
        self.counters["row_copies"] += 1
        return run, row

    def alloc_run(self, nb: int) -> tuple[np.ndarray, int]:
        """A cold serve-slot run: ``nb`` reset blocks + one zeroed row."""
        return self.alloc_blocks(nb), int(self.alloc_rows(1)[0])

    def adopt(self, blocks: np.ndarray, row: int,
              covered_blocks: int) -> PagedHandle:
        """Turn an owned serve-slot run into a session handle, trimming to
        ``covered_blocks`` (the rest is freed — the density win of paged
        retirement: a session keeps O(len), not O(max_len))."""
        blocks = np.asarray(blocks, np.int32)
        keep, drop = blocks[:covered_blocks], blocks[covered_blocks:]
        if len(drop):
            self.free_blocks(drop)
        return self.register(keep[None], np.array([row], np.int32))

    def trim(self, handle: PagedHandle, covered_blocks: int) -> None:
        """Free blocks past ``covered_blocks`` in every row of ``handle``
        (positions there were never written — re-extension resets fresh
        blocks to the same all-zero contents)."""
        if covered_blocks >= handle.tables.shape[1]:
            return
        self.free_blocks(handle.tables[:, covered_blocks:])
        handle.tables = handle.tables[:, :covered_blocks].copy()
