"""HLO live-buffer accounting for the kernel-first decode path.

The gathered-view paged decode (``attn_decode_impl="gather"``) materialises
the O(B * S) slot-linear attention KV view every dispatch; the kernel-first
path must never allocate it.  These probes make that checkable: derive the
HLO type strings of every buffer the gathered view would create, lower the
decode-scan executable, and scan its HLO text for them.  Used by
``tests/test_kernel_decode.py`` and enforced in CI through
``benchmarks/decode_microbench.py --check-hlo``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T

_HLO_DTYPE = {"bfloat16": "bf16", "float32": "f32", "float16": "f16"}


def slot_linear_kv_types(cfg, cache: dict, block_len: int) -> set[str]:
    """HLO type strings (e.g. ``bf16[3,128,3,64]``) of every attention
    k/v leaf the slot-linear gathered view would materialise for this
    paged cache — the O(B * S) buffers ``paged_gather`` creates and the
    kernel-first path must never allocate.  O(B) leaves (recurrent state
    rows, conv tails) are excluded: the kernel path still gathers those."""
    view_lens = {cache["table"].shape[1] * block_len}
    if cfg.window is not None:
        view_lens.add(cfg.window)               # local-attention ring view
    gathered = jax.eval_shape(lambda c: T.paged_gather(cfg, c), cache)
    out = set()
    for leaf in jax.tree_util.tree_leaves(gathered):
        if (leaf.ndim >= 4 and leaf.shape[-3] in view_lens
                and not jnp.issubdtype(leaf.dtype, jnp.integer)):
            dt = _HLO_DTYPE.get(leaf.dtype.name, leaf.dtype.name)
            out.add(f"{dt}[{','.join(map(str, leaf.shape))}]")
    return out


def decode_hlo(eng, impl: str, prompts, steps: int = 4) -> tuple[str, set]:
    """Compiled HLO text of the engine's decode-scan executable for the
    given impl, plus the slot-linear view types for its cache shape."""
    from repro.serving.engine import _decode_scan_paged

    st = eng.absorb(prompts)
    cache, _ = eng._paged_grown(st, st.offset + steps)
    lowered = _decode_scan_paged.lower(
        eng.params, eng.cfg, st.cur, st.last, cache, st.pos,
        jax.random.PRNGKey(0), eng.ucfg, steps, True, impl=impl)
    txt = lowered.compile().as_text()
    return txt, slot_linear_kv_types(eng.cfg, cache, eng.block_len)


def assert_no_slot_linear_kv(eng_gather, eng_kernel, prompts,
                             steps: int = 4) -> dict:
    """Probe-soundness + kernel-first assertion in one shot: the gather
    executable must CARRY the slot-linear view (else the probe is vacuous)
    and the kernel-first executable must NOT.  Returns the accounting dict
    for reporting; raises AssertionError on violation."""
    txt_g, types_g = decode_hlo(eng_gather, "gather", prompts, steps)
    txt_k, types_k = decode_hlo(eng_kernel, "kernel", prompts, steps)
    assert types_g == types_k and types_g, "probe derived no view types"
    present = sorted(t for t in types_g if t in txt_g)
    assert present, ("probe unsound: gather executable lacks the "
                     f"slot-linear view {sorted(types_g)}")
    leaked = sorted(t for t in types_k if t in txt_k)
    assert not leaked, (
        f"kernel-first decode still materialises the slot-linear KV view: "
        f"{leaked}")
    return {"view_types": sorted(types_g), "in_gather_hlo": present,
            "in_kernel_hlo": leaked}
