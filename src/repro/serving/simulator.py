"""Event-level network / failure simulator (paper Sec. III-B environment).

The prototype measured wall-clock on one desktop; this container is CPU-only,
so end-to-end latencies come from a calibrated stochastic model instead
(constants in core/cost_model.LatencyParams, fitted to Table III):

  * WAN: lognormal RTT + two-state Markov availability (outages, O5 tests)
  * local links: per-peer Gaussian jitter (Eq. 9's L_comm)
  * nodes: Bernoulli-per-window failures with exponential recovery
    (straggler/fault injection for the quorum experiments)

All routing/consensus/budget code that the simulator drives is the REAL
production code — only link/compute *timings* are synthetic.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cost_model import LatencyParams


@dataclasses.dataclass
class SimConfig:
    seed: int = 0
    wan_outage_p: float = 0.02       # P(up -> down) per query
    wan_recover_p: float = 0.5       # P(down -> up) per query
    node_fail_p: float = 0.0         # per-query member failure probability
    node_recover_p: float = 0.5
    straggler_p: float = 0.05        # peer responds ~5x slower
    straggler_mult: float = 5.0


class NetworkSimulator:
    def __init__(self, cfg: SimConfig, lat: LatencyParams, n_members: int):
        self.cfg = cfg
        self.lat = lat
        self.rng = np.random.RandomState(cfg.seed)
        self.wan_up = True
        self.member_up = np.ones((n_members,), bool)

    # --- state evolution (called once per query/batch tick) ---------------
    def tick(self):
        c = self.cfg
        if self.wan_up:
            self.wan_up = self.rng.rand() >= c.wan_outage_p
        else:
            self.wan_up = self.rng.rand() < c.wan_recover_p
        for j in range(len(self.member_up)):
            if self.member_up[j]:
                self.member_up[j] = self.rng.rand() >= c.node_fail_p
            else:
                self.member_up[j] = self.rng.rand() < c.node_recover_p

    # --- latency samples ----------------------------------------------------
    def wan_rtt(self, n: int) -> np.ndarray:
        mu, sd = self.lat.wan_rtt_mean, self.lat.wan_rtt_std
        sigma2 = np.log(1 + (sd / mu) ** 2)
        return self.rng.lognormal(np.log(mu) - sigma2 / 2, np.sqrt(sigma2), n)

    def peer_comm(self, n_queries: int, n_members: int) -> np.ndarray:
        base = np.abs(self.rng.normal(self.lat.comm_peer_mean,
                                      self.lat.comm_peer_std,
                                      (n_queries, n_members)))
        straggle = self.rng.rand(n_queries, n_members) < self.cfg.straggler_p
        return np.where(straggle, base * self.cfg.straggler_mult, base)

    def edge_latency(self, token_counts: np.ndarray) -> np.ndarray:
        sg = self.lat.edge_jitter_sigma
        jitter = self.rng.lognormal(-sg * sg / 2, sg, np.shape(token_counts))
        return (self.lat.edge_prefill
                + self.lat.edge_per_token * token_counts) * jitter

    def cloud_latency(self, token_counts: np.ndarray) -> np.ndarray:
        return self.wan_rtt(len(np.atleast_1d(token_counts))) \
            + self.lat.cloud_per_token * token_counts
