"""Event-level network / failure simulator (paper Sec. III-B environment).

The prototype measured wall-clock on one desktop; this container is CPU-only,
so end-to-end latencies come from a calibrated stochastic model instead
(constants in core/cost_model.LatencyParams, fitted to Table III):

  * WAN: lognormal RTT + two-state Markov availability (outages, O5 tests)
  * local links: per-peer Gaussian jitter (Eq. 9's L_comm)
  * nodes: per-tick Bernoulli failure AND recovery — a down node comes
    back with probability ``node_recover_p`` each tick, i.e. downtime is
    geometrically distributed with mean ``1 / node_recover_p`` ticks
    (the discrete-time analogue of exponential recovery; see
    ``SimConfig.mean_ticks_to_recover``).  The WAN uses the same
    two-state chain with ``wan_outage_p`` / ``wan_recover_p``.

All routing/consensus/budget code that the simulator drives is the REAL
production code — only link/compute *timings* are synthetic.  Failures
here only shape *availability and latency accounting*; execution-level
failures (a member call actually raising mid-round) are injected by
serving/faults.py.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cost_model import LatencyParams


@dataclasses.dataclass
class SimConfig:
    """Two-state Markov availability knobs, one tick per gateway batch.

    All four transition probabilities are per-tick Bernoulli draws, so
    sojourn times are geometric: a WAN outage lasts ``1/wan_recover_p``
    ticks in expectation, a node outage ``1/node_recover_p`` ticks.
    """

    seed: int = 0
    wan_outage_p: float = 0.02       # P(up -> down) per tick
    wan_recover_p: float = 0.5       # P(down -> up) per tick
    node_fail_p: float = 0.0         # P(up -> down) per tick, per member
    node_recover_p: float = 0.5      # P(down -> up) per tick, per member
    straggler_p: float = 0.05        # peer responds ~5x slower
    straggler_mult: float = 5.0

    def mean_ticks_to_recover(self, kind: str = "node") -> float:
        """Expected outage length in ticks (geometric mean sojourn):
        ``1 / recover_p``, infinite when recovery is disabled."""
        p = self.node_recover_p if kind == "node" else self.wan_recover_p
        return float("inf") if p <= 0 else 1.0 / p


class NetworkSimulator:
    def __init__(self, cfg: SimConfig, lat: LatencyParams, n_members: int):
        self.cfg = cfg
        self.lat = lat
        self.n_members = n_members
        self.reset()

    def reset(self):
        """Rewind to the seeded initial state (determinism re-runs)."""
        self.rng = np.random.RandomState(self.cfg.seed)
        self.wan_up = True
        self.member_up = np.ones((self.n_members,), bool)

    # --- state evolution (called once per query/batch tick) ---------------
    def tick(self):
        c = self.cfg
        if self.wan_up:
            self.wan_up = self.rng.rand() >= c.wan_outage_p
        else:
            self.wan_up = self.rng.rand() < c.wan_recover_p
        for j in range(len(self.member_up)):
            if self.member_up[j]:
                self.member_up[j] = self.rng.rand() >= c.node_fail_p
            else:
                self.member_up[j] = self.rng.rand() < c.node_recover_p

    # --- latency samples ----------------------------------------------------
    def wan_rtt(self, n: int) -> np.ndarray:
        mu, sd = self.lat.wan_rtt_mean, self.lat.wan_rtt_std
        sigma2 = np.log(1 + (sd / mu) ** 2)
        return self.rng.lognormal(np.log(mu) - sigma2 / 2, np.sqrt(sigma2), n)

    def peer_comm(self, n_queries: int, n_members: int) -> np.ndarray:
        base = np.abs(self.rng.normal(self.lat.comm_peer_mean,
                                      self.lat.comm_peer_std,
                                      (n_queries, n_members)))
        straggle = self.rng.rand(n_queries, n_members) < self.cfg.straggler_p
        return np.where(straggle, base * self.cfg.straggler_mult, base)

    def edge_latency(self, token_counts: np.ndarray) -> np.ndarray:
        sg = self.lat.edge_jitter_sigma
        jitter = self.rng.lognormal(-sg * sg / 2, sg, np.shape(token_counts))
        return (self.lat.edge_prefill
                + self.lat.edge_per_token * token_counts) * jitter

    def cloud_latency(self, token_counts: np.ndarray) -> np.ndarray:
        return self.wan_rtt(len(np.atleast_1d(token_counts))) \
            + self.lat.cloud_per_token * token_counts
