"""Execution-level fault injection + failure-domain primitives.

serving/simulator.py perturbs *latency accounting* (a down member costs
nothing, a WAN outage reroutes); this module makes failures happen in
*execution*: a member's generate/serve raises mid-round, the cloud call
times out, the pool refuses blocks, a live session is evicted.  The
resilience layer (gateway retry/breaker, swarm casualty salvage, serve
backpressure) is exercised against these injected faults and must keep
every query answered.

Three pieces:

* a typed exception hierarchy rooted at ``ServingFault(RuntimeError)`` —
  ``PoolExhaustedError`` replaces the bare famine ``RuntimeError`` the
  cache pool used to raise (breaking change, see docs/RUNTIME.md);
* ``FaultPlan``: a deterministic, seeded schedule of ``FaultEvent``s,
  consulted at execution choke points (``call``/``consume``).  Determinism
  contract: the same plan spec + seed against the same workload produces
  the same injected faults, the same winners and the same counters —
  and an EMPTY plan (or ``faults=None``) leaves execution bitwise
  untouched, because no code path draws from ``plan.rng`` or consults
  the schedule result unless an event actually fires;
* retry/health machinery the gateway composes: ``RetryPolicy`` (bounded
  attempts, deadline, jittered exponential backoff), ``CircuitBreaker``
  (closed -> open -> half-open over gateway ticks), ``HealthRegistry``
  (per-member EWMA latency + consecutive-failure count with half-open
  recovery probes, fed to ``scheduler.select_peers``).
"""

from __future__ import annotations

import dataclasses

import numpy as np


# ---------------------------------------------------------------------------
# Typed exception hierarchy
# ---------------------------------------------------------------------------

class ServingFault(RuntimeError):
    """Base of the serving failure domain.

    Subclasses ``RuntimeError`` so pre-existing ``except RuntimeError``
    call sites keep catching pool famine after the rename.
    """

    #: simulated seconds burned before the failure surfaced (e.g. a call
    #: that timed out consumed its full deadline).  Latency accounting
    #: adds this even though the call produced nothing.
    delay_s: float = 0.0


class MemberDownError(ServingFault):
    """A swarm member crashed / became unreachable mid-round."""

    def __init__(self, msg: str, member: int | None = None):
        super().__init__(msg)
        self.member = member


class CloudUnavailableError(ServingFault):
    """Cloud summon failed (timeout, transport error, or open breaker)."""


class PoolExhaustedError(ServingFault):
    """Block pool famine: no admission possible even after TTL eviction.

    Replaces the bare ``RuntimeError`` previously raised by
    ``CachePool.alloc_blocks``/``alloc_rows`` and ``serve()``.
    """


# ---------------------------------------------------------------------------
# Fault plan
# ---------------------------------------------------------------------------

#: recognised (site, kind) combinations; ``member:<j>`` matches member j.
SITES = ("cloud", "member", "pool", "session", "slot", "decode")
KINDS = ("crash", "timeout", "error", "straggle", "famine", "evict", "fail")


@dataclasses.dataclass
class FaultEvent:
    """One scheduled failure.

    site:  "cloud" | "member:<j>" | "pool" | "session" | "slot" | "decode"
    kind:  "crash"    — call raises immediately (no latency burned)
           "timeout"  — call raises after burning ``delay_s`` (the caller's
                        deadline); retried by the gateway's RetryPolicy
           "error"    — transport error, raises immediately.  Flaky-then-
                        succeed is expressed with ``count`` < the caller's
                        retry budget: the first ``count`` calls fail, the
                        next succeeds.
           "straggle" — call succeeds but ``delay_s`` is added to its
                        realized latency ("decode" site: per decode chunk)
           "famine"   — ("pool") one admission round sees zero free blocks
           "evict"    — ("session") the next warm admission finds its
                        handle evicted (forces the cold re-prefill path)
           "fail"     — ("slot") the lowest active decode slot dies after
                        the current chunk; its request is requeued
    tick:  fire only at this plan tick (None = first opportunity)
    count: how many consecutive matching calls/rounds are affected
    delay_s: simulated seconds for timeout/straggle kinds
    """

    site: str
    kind: str
    tick: int | None = None
    count: int = 1
    delay_s: float = 0.0


class FaultPlan:
    """Deterministic, seeded schedule of execution faults.

    The plan is consulted at choke points, never wrapped around engines
    (the gateway's ``m is self.probe`` identity checks must keep working).
    ``call(site, fn, ...)`` is the main entry: it either runs ``fn`` (and
    reports any injected straggle delay) or raises the typed exception
    the site maps to.  ``consume(site)`` is the non-callable form for
    sites that gate control flow (famine, evict, slot).

    ``rng`` is plan-owned: retry backoff jitter draws from it so the
    simulator's RNG stream is untouched — a prerequisite for the
    "empty plan == bitwise pre-PR behavior" contract.
    """

    def __init__(self, events: list[FaultEvent] | tuple = (), seed: int = 0):
        self._spec = tuple(dataclasses.replace(e) for e in events)
        self.seed = seed
        self.reset()

    def reset(self):
        """Rewind to tick 0 with the original schedule (for determinism
        re-runs: same spec + seed -> same injections)."""
        self.events = [dataclasses.replace(e) for e in self._spec]
        self.rng = np.random.RandomState(self.seed)
        self._tick = 0
        self.counters: dict[str, int] = {}

    def tick(self):
        """Advance the plan clock (the gateway calls this once per batch)."""
        self._tick += 1

    @property
    def now(self) -> int:
        return self._tick

    # -- schedule queries ---------------------------------------------------
    def _match(self, site: str) -> FaultEvent | None:
        for ev in self.events:
            if ev.site == site and ev.count > 0 and (
                    ev.tick is None or ev.tick == self._tick):
                return ev
        return None

    def pending(self, site: str) -> bool:
        """Is a fault armed for this site at the current tick? (no consume)"""
        return self._match(site) is not None

    def consume(self, site: str) -> FaultEvent | None:
        """Pop one scheduled fault for ``site`` (None if none armed)."""
        ev = self._match(site)
        if ev is None:
            return None
        ev.count -= 1
        key = f"{site}:{ev.kind}"
        self.counters[key] = self.counters.get(key, 0) + 1
        return ev

    # -- the execution choke point -----------------------------------------
    def call(self, site: str, fn, *args, **kwargs):
        """Run ``fn`` at a fault site -> ``(result, injected_delay_s)``.

        Raises ``CloudUnavailableError`` (site "cloud") or
        ``MemberDownError`` (sites "member:<j>") when a crash/timeout/
        error event is armed; a "straggle" event lets the call through
        but reports its delay for latency accounting.
        """
        ev = self.consume(site)
        if ev is None:
            return fn(*args, **kwargs), 0.0
        if ev.kind == "straggle":
            return fn(*args, **kwargs), float(ev.delay_s)
        member = int(site.split(":", 1)[1]) if site.startswith("member:") else None
        cls = CloudUnavailableError if site == "cloud" else MemberDownError
        err = (cls(f"injected {ev.kind} at {site} (tick {self._tick})")
               if member is None else
               cls(f"injected {ev.kind} at {site} (tick {self._tick})", member))
        err.delay_s = float(ev.delay_s) if ev.kind == "timeout" else 0.0
        raise err

    # -- seeded schedule generation ----------------------------------------
    @classmethod
    def random(cls, seed: int, n_members: int, ticks: int, *,
               p_member_crash: float = 0.05, p_cloud_fail: float = 0.05,
               p_straggle: float = 0.1, p_famine: float = 0.0,
               straggle_s: float = 1.0, timeout_s: float = 8.0) -> "FaultPlan":
        """Draw a deterministic schedule from ``seed`` (chaos harnesses)."""
        rng = np.random.RandomState(seed)
        events: list[FaultEvent] = []
        for t in range(1, ticks + 1):
            for j in range(n_members):
                r = rng.rand()
                if r < p_member_crash:
                    events.append(FaultEvent(f"member:{j}", "crash", tick=t))
                elif r < p_member_crash + p_straggle:
                    events.append(FaultEvent(f"member:{j}", "straggle",
                                             tick=t, delay_s=straggle_s))
            if rng.rand() < p_cloud_fail:
                events.append(FaultEvent("cloud", "timeout", tick=t,
                                         delay_s=timeout_s))
            if rng.rand() < p_famine:
                events.append(FaultEvent("pool", "famine", tick=t))
        return cls(events, seed=seed)


# ---------------------------------------------------------------------------
# Retry / breaker / health
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with deadline + jittered exponential backoff.

    ``timeout_s`` is the per-attempt deadline: a summon that fails with a
    timeout burns the full deadline before the next attempt; backoff
    sleeps are added on top.  All of it is *simulated* time fed into the
    Eq. 9-style latency accounting — nothing actually sleeps.
    """

    max_attempts: int = 3
    timeout_s: float = 8.0
    backoff_base_s: float = 0.25
    backoff_mult: float = 2.0
    jitter: float = 0.25          # +/- fraction of the nominal backoff

    def backoff(self, attempt: int, rng: np.random.RandomState | None = None
                ) -> float:
        """Backoff before retry #``attempt`` (0-indexed: after failure 1)."""
        b = self.backoff_base_s * self.backoff_mult ** attempt
        if rng is not None and self.jitter > 0:
            b *= 1.0 + self.jitter * (2.0 * rng.rand() - 1.0)
        return float(b)


class CircuitBreaker:
    """Cloud-summon circuit breaker over gateway batch ticks.

    closed -> (``fail_threshold`` consecutive exhausted summons) -> open
    -> (``cooldown_ticks`` later) -> half-open: one probe summon is let
    through; success re-closes, failure re-opens.  While open,
    ``allow() == False`` degrades routing exactly like a WAN outage
    (``wan_ok`` and the breaker AND into one ``cloud_ok`` signal).
    """

    def __init__(self, fail_threshold: int = 1, cooldown_ticks: int = 2):
        self.fail_threshold = fail_threshold
        self.cooldown_ticks = cooldown_ticks
        self.reset()

    def reset(self):
        self.state = "closed"
        self.consecutive_failures = 0
        self.opened_at = -1
        self.opened_count = 0

    def allow(self, tick: int) -> bool:
        if self.state == "open":
            if tick - self.opened_at >= self.cooldown_ticks:
                self.state = "half-open"
                return True
            return False
        return True

    def record_success(self):
        self.state = "closed"
        self.consecutive_failures = 0

    def record_failure(self, tick: int):
        self.consecutive_failures += 1
        if (self.state == "half-open"
                or self.consecutive_failures >= self.fail_threshold):
            self.state = "open"
            self.opened_at = tick
            self.opened_count += 1


class HealthRegistry:
    """Per-member health: EWMA latency + consecutive-failure count.

    A member whose consecutive failures reach ``fail_threshold`` stops
    being ``available()`` — except every ``probe_interval`` ticks, when
    it is offered as a half-open recovery probe; one success restores it.
    ``scheduler.select_peers(..., health=...)`` masks selection with
    ``available()`` and uses the EWMA as the latency prior where known.
    """

    def __init__(self, n: int, alpha: float = 0.3, fail_threshold: int = 2,
                 probe_interval: int = 3):
        self.n = n
        self.alpha = alpha
        self.fail_threshold = fail_threshold
        self.probe_interval = probe_interval
        self.ewma = np.full((n,), np.nan)
        self.fails = np.zeros((n,), np.int64)
        self._tick = 0
        self._down_at = np.full((n,), -1, np.int64)

    def tick(self):
        self._tick += 1

    def record_success(self, j: int, latency_s: float | None = None):
        self.fails[j] = 0
        self._down_at[j] = -1
        if latency_s is not None:
            self.ewma[j] = (latency_s if np.isnan(self.ewma[j]) else
                            self.alpha * latency_s
                            + (1 - self.alpha) * self.ewma[j])

    def record_failure(self, j: int):
        self.fails[j] += 1
        if self.fails[j] == self.fail_threshold:
            self._down_at[j] = self._tick

    def healthy(self) -> np.ndarray:
        return self.fails < self.fail_threshold

    def available(self) -> np.ndarray:
        """Healthy members, plus unhealthy ones due a half-open probe."""
        h = self.healthy()
        since = self._tick - self._down_at
        probe = (~h) & (self._down_at >= 0) & (since > 0) \
            & (since % self.probe_interval == 0)
        return h | probe
