"""Swarm executor: heterogeneous peer collaboration + weighted consensus.

Runs up to k peer engines on the same query batch, clusters answers by
exact token sequence, and applies the Eq. 14 uncertainty-weighted consensus
(core/consensus.py).  Quorum mode (beyond-paper straggler mitigation) takes
the fastest `quorum` members' answers — under the simulator this turns
Eq. 9's max() into an order statistic and bounds swarm tail latency.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.consensus import PAD, batched_consensus
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import Request


def pad_prompts(prompts: Sequence[Sequence[int]], length: int | None = None,
                align: str = "left") -> np.ndarray:
    """Pad variable-length prompts with PAD=0 into (B, S).

    align="left" (left-pad, HF batched-decode convention) for generation;
    align="right" (right-pad) for the safety classifier, matching its
    training layout."""
    length = length or max(len(p) for p in prompts)
    out = np.zeros((len(prompts), length), np.int32)
    for i, p in enumerate(prompts):
        p = list(p)[-length:]
        if align == "left":
            out[i, length - len(p):] = p
        else:
            out[i, :len(p)] = p
    return out


def truncate_at_stop(tokens: np.ndarray, stop_token: int | None) -> np.ndarray:
    """Answer normalisation (paper's lowercase/collapse analogue): keep
    tokens up to and excluding the first stop token, PAD the rest — so
    cross-model clustering compares *answers*, not trailing continuations."""
    if stop_token is None:
        return tokens
    out = tokens.copy()
    hit = np.cumsum(tokens == stop_token, axis=-1) > 0
    out[hit] = PAD
    return out


@dataclasses.dataclass
class SwarmExecutor:
    members: list[InferenceEngine]
    w_min: float = 0.05
    stop_token: int | None = None
    streaming: bool = False      # route rounds through each member's serve()
    serve_slots: int = 4         # decode slots when streaming

    def collaborate(self, prompts: np.ndarray, max_new: int, *,
                    member_mask: np.ndarray | None = None,
                    seed: int = 0,
                    precomputed: dict[int, tuple] | None = None) -> dict:
        """prompts (B, S). member_mask (n,) bool marks *available* members
        (node-failure injection / quorum selection excludes the rest).

        Each member answers the whole round in ONE batched engine invocation
        (jitted prefill + scanned decode).  ``streaming=True`` instead feeds
        the round through the member's continuous-batching ``serve`` path —
        same greedy tokens, but sized for requests that arrive over time,
        not for a round that is known upfront.  ``precomputed`` maps member
        index -> (tokens (B, N), u (B,)) for members whose generations the
        caller already has (the gateway's probe), so they are not re-run.

        Returns ``{"answers": (B, n, N) per-member tokens, "u": (B, n)
        Eq. 4 difficulties, "winner_tokens": (B, N), "winner_member":
        (B,), "consensus_score": (B,) best Eq. 14 cluster score,
        "scores": (B, n)}``.
        """
        n = len(self.members)
        B = prompts.shape[0]
        if member_mask is None:
            member_mask = np.ones((n,), bool)

        answers = np.full((B, n, max_new), PAD, np.int32)
        u = np.ones((B, n), np.float32)            # unavailable => weight w_min
        for j, eng in enumerate(self.members):
            if not member_mask[j]:
                continue
            if precomputed is not None and j in precomputed:
                toks, uj = precomputed[j]
            elif self.streaming:
                # the padded row (incl. leading PADs) is the request prompt,
                # so per-request absorption matches batched generation
                reqs = [Request(rid=i, prompt=prompts[i].tolist(),
                                max_new=max_new) for i in range(B)]
                fin = eng.serve(reqs, n_slots=min(B, self.serve_slots),
                                seed=seed + j)
                toks = np.zeros((B, max_new), np.int32)
                uj = np.ones((B,), np.float32)
                for r in fin:
                    toks[r["rid"], :len(r["tokens"])] = r["tokens"]
                    uj[r["rid"]] = r["u"]
            else:
                res = eng.generate(prompts, max_new, seed=seed + j)
                toks, uj = res["tokens"], res["u"]
            answers[:, j, :] = truncate_at_stop(np.asarray(toks, np.int32),
                                                self.stop_token)
            u[:, j] = uj

        # unavailable members keep PAD answers; give them zero support by
        # grouping them into a sentinel cluster with weight w_min (paper's
        # floor) — exact-match keeps them away from real clusters.
        res = batched_consensus(jnp.asarray(answers), jnp.asarray(u),
                                w_min=self.w_min)
        rep = np.asarray(res.rep_index)
        winners = answers[np.arange(B), rep]
        return {
            "answers": answers,                       # (B, n, N)
            "u": u,                                   # (B, n)
            "winner_tokens": winners,                 # (B, N)
            "winner_member": rep,                     # (B,)
            "consensus_score": np.asarray(res.best_score),  # (B,)
            "scores": np.asarray(res.scores),         # (B, n)
        }
