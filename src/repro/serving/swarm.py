"""Swarm executor: heterogeneous peer collaboration + weighted consensus.

Runs up to k peer engines on the same query batch, clusters answers by
exact token sequence, and applies the Eq. 14 uncertainty-weighted consensus
(core/consensus.py).  Quorum mode (beyond-paper straggler mitigation) takes
the fastest `quorum` members' answers — under the simulator this turns
Eq. 9's max() into an order statistic and bounds swarm tail latency.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import uncertainty as U
from repro.core.consensus import PAD, batched_consensus
from repro.serving.engine import InferenceEngine
from repro.serving.faults import FaultPlan, MemberDownError
from repro.serving.scheduler import Request


def pad_prompts(prompts: Sequence[Sequence[int]], length: int | None = None,
                align: str = "left") -> np.ndarray:
    """Pad variable-length prompts with PAD=0 into (B, S).

    align="left" (left-pad, HF batched-decode convention) for generation;
    align="right" (right-pad) for the safety classifier, matching its
    training layout."""
    length = length or max(len(p) for p in prompts)
    out = np.zeros((len(prompts), length), np.int32)
    for i, p in enumerate(prompts):
        p = list(p)[-length:]
        if align == "left":
            out[i, length - len(p):] = p
        else:
            out[i, :len(p)] = p
    return out


def truncate_at_stop(tokens: np.ndarray, stop_token: int | None) -> np.ndarray:
    """Answer normalisation (paper's lowercase/collapse analogue): keep
    tokens up to and excluding the first stop token, PAD the rest — so
    cross-model clustering compares *answers*, not trailing continuations."""
    if stop_token is None:
        return tokens
    out = tokens.copy()
    hit = np.cumsum(tokens == stop_token, axis=-1) > 0
    out[hit] = PAD
    return out


def answer_mask(tokens: np.ndarray, stop_token: int | None) -> np.ndarray:
    """Bool mask of the *answer* span: positions up to and INCLUDING the
    first stop token (everything a request would have decoded before
    retiring).  Eq. 2-4 difficulty restricted to this span matches the
    streaming serve path's accumulation — post-answer entropy is not
    folded into u."""
    if stop_token is None:
        return np.ones(tokens.shape, bool)
    eq = tokens == stop_token
    hit = np.cumsum(eq, axis=-1)
    return (hit == 0) | ((hit == 1) & eq)


@dataclasses.dataclass
class SwarmExecutor:
    members: list[InferenceEngine]
    w_min: float = 0.05
    stop_token: int | None = None
    streaming: bool = False      # route rounds through each member's serve()
    serve_slots: int = 4         # decode slots when streaming
    # execution-level fault injection (serving/faults.py): member calls
    # run through plan sites "member:<j>" — a crash/timeout drops that
    # member's candidates for the round (quorum salvage: consensus
    # renormalizes over survivors), a straggle reports its delay for the
    # gateway's Eq. 9 accounting.  Streaming members forward the plan
    # into serve() (famine/evict/slot sites) with overload="shed" so a
    # member-side famine degrades to PAD answers instead of crashing
    # the round.  None (default) leaves execution bitwise untouched.
    faults: FaultPlan | None = None

    def collaborate(self, prompts: np.ndarray, max_new: int, *,
                    member_mask: np.ndarray | None = None,
                    seed: int = 0,
                    precomputed: dict[int, tuple] | None = None,
                    states: dict[int, object] | None = None) -> dict:
        """prompts (B, S). member_mask (n,) bool marks *available* members
        (node-failure injection / quorum selection excludes the rest).

        Each member answers the whole round in ONE batched engine invocation
        (jitted prefill + scanned decode).  ``streaming=True`` instead feeds
        the round through the member's continuous-batching ``serve`` path —
        same greedy tokens, but sized for requests that arrive over time,
        not for a round that is known upfront.  Requests retire at
        ``stop_token``, so streamed and batched rounds agree on answers
        AND on u: the batched path masks its Eq. 2-4 difficulty to the
        answer span (up to and including the stop token — ``answer_mask``),
        matching what the streaming path accumulates before retirement.

        ``precomputed`` maps member index -> (tokens (B, N), u (B,)[,
        (h_mean, v_mean)]) for members whose generations the caller already
        has (the gateway's probe), so they are not re-run — the round
        issues ZERO prefill dispatches for them.  ``states`` maps member
        index -> the matching ``SessionState`` warm-cache handle; when the
        round wants a longer answer than the precomputed one (escalation
        deepening), the member *extends* its generation decode-only from
        the live cache instead of re-prefilling the prompt, and u is
        re-averaged over the full span from the provided raw Eq. 2-3 means.
        On a paged member the handoff the gateway builds (``state_select``)
        is a refcounted block-TABLE copy — O(table), not O(cache) — and
        the extension's first write copy-on-writes the shared tail block
        (docs/RUNTIME.md "Paged caches & prefix sharing").

        Returns ``{"answers": (B, n, N) per-member tokens, "u": (B, n)
        Eq. 4 difficulties, "winner_tokens": (B, N), "winner_member":
        (B,), "consensus_score": (B,) best Eq. 14 cluster score,
        "scores": (B, n)}``.
        """
        n = len(self.members)
        B = prompts.shape[0]
        if member_mask is None:
            member_mask = np.ones((n,), bool)

        answers = np.full((B, n, max_new), PAD, np.int32)
        u = np.ones((B, n), np.float32)            # unavailable => weight w_min
        casualties: list[int] = []
        straggle: dict[int, float] = {}
        for j, eng in enumerate(self.members):
            if not member_mask[j]:
                continue

            def run(j=j, eng=eng):
                if precomputed is not None and j in precomputed:
                    toks, uj = precomputed[j][0], precomputed[j][1]
                    toks = np.asarray(toks, np.int32)
                    n_pre = toks.shape[1]
                    if n_pre < max_new:
                        if states is None or j not in states:
                            raise ValueError(
                                f"member {j}: precomputed answer covers "
                                f"{n_pre} < {max_new} tokens and no session"
                                " state was provided to extend it from")
                        # decode-only continuation off the warm cache: the
                        # extension emits exactly the tokens a longer
                        # original generation would have produced next —
                        # zero prefills
                        ext = eng.generate(None, max_new - n_pre,
                                           state=states[j], seed=seed + j)
                        pre_toks = toks
                        toks = np.concatenate([toks, ext["tokens"]], axis=1)
                        if len(precomputed[j]) > 2:
                            uj = self._deepened_u(eng, pre_toks, ext,
                                                  precomputed[j][2], uj)
                    return toks, uj
                if self.streaming:
                    # the padded row (incl. leading PADs) is the request
                    # prompt, so per-request absorption matches batched
                    # generation
                    reqs = [Request(rid=i, prompt=prompts[i].tolist(),
                                    max_new=max_new) for i in range(B)]
                    fin = eng.serve(reqs, n_slots=min(B, self.serve_slots),
                                    stop_token=self.stop_token, seed=seed + j,
                                    faults=self.faults, overload="shed")
                    toks = np.zeros((B, max_new), np.int32)
                    uj = np.ones((B,), np.float32)
                    for r in fin:
                        if r.get("shed"):
                            continue   # PAD answer + u=1 => w_min sentinel
                        toks[r["rid"], :len(r["tokens"])] = r["tokens"]
                        uj[r["rid"]] = r["u"]
                    return toks, uj
                res = eng.generate(prompts, max_new, seed=seed + j)
                # mask u to the answer span so batched and streaming
                # rounds score identically (no post-answer entropy)
                return res["tokens"], self.member_u(eng, res)

            if self.faults is None:
                toks, uj = run()
            else:
                try:
                    (toks, uj), delay = self.faults.call(f"member:{j}", run)
                except MemberDownError:
                    # casualty: keep PAD answers + u=1.0, the same
                    # sentinel-cluster/w_min floor an unavailable member
                    # gets — consensus renormalizes over survivors and
                    # quorum is satisfied by whoever returned
                    casualties.append(j)
                    continue
                if delay:
                    straggle[j] = delay
            answers[:, j, :] = truncate_at_stop(np.asarray(toks, np.int32),
                                                self.stop_token)
            u[:, j] = uj

        # unavailable members keep PAD answers; give them zero support by
        # grouping them into a sentinel cluster with weight w_min (paper's
        # floor) — exact-match keeps them away from real clusters.
        res = batched_consensus(jnp.asarray(answers), jnp.asarray(u),
                                w_min=self.w_min)
        rep = np.asarray(res.rep_index)
        winners = answers[np.arange(B), rep]
        return {
            "answers": answers,                       # (B, n, N)
            "u": u,                                   # (B, n)
            "winner_tokens": winners,                 # (B, N)
            "winner_member": rep,                     # (B,)
            "consensus_score": np.asarray(res.best_score),  # (B,)
            "scores": np.asarray(res.scores),         # (B, n)
            # failure-domain report: members that crashed mid-round (the
            # gateway refunds their Eq. 9 edge-latency term and records
            # the failure in its health registry) and injected straggler
            # delays in seconds (added to that member's comm term)
            "casualties": casualties,                 # list[int]
            "straggle_s": straggle,                   # {member: seconds}
        }

    def _deepened_u(self, eng: InferenceEngine, pre_toks: np.ndarray,
                    ext: dict, pre_terms: tuple,
                    uj: np.ndarray) -> np.ndarray:
        """u for a member whose precomputed answer was extended decode-only.

        Scored over the same answer span ``member_u`` uses for everyone
        else: with no stop token, the caller's raw Eq. 2-3 means re-average
        over the full span; with one, extension terms are masked to the
        answer and rows whose answer already ended inside the prefix keep
        the caller's (answer-span) u untouched.
        """
        h1, v1 = pre_terms
        n_pre = pre_toks.shape[1]
        k = ext["tokens"].shape[1]
        if self.stop_token is None:
            h = (h1 * n_pre + ext["h_mean"] * k) / (n_pre + k)
            v = (v1 * n_pre + ext["v_mean"] * k) / (n_pre + k)
            return np.asarray(U.combine_terms(h, v, eng.ucfg))
        if ext.get("logits") is None:
            return uj            # can't mask the extension terms: keep the
                                 # caller's answer-span u (conservative)
        full_mask = answer_mask(
            np.concatenate([pre_toks, ext["tokens"]], axis=1),
            self.stop_token)
        prefix_clean = full_mask[:, :n_pre].all(axis=1)
        ext_mask = full_mask[:, n_pre:]
        h2, v2 = U.uncertainty_terms(ext["logits"],
                                     jnp.asarray(ext["tokens"]), eng.ucfg)
        n2 = ext_mask.sum(axis=1)
        d = n_pre + n2
        h = (h1 * n_pre + (np.asarray(h2) * ext_mask).sum(axis=1)) / d
        v = (v1 * n_pre + (np.asarray(v2) * ext_mask).sum(axis=1)) / d
        return np.where(prefix_clean,
                        np.asarray(U.combine_terms(h, v, eng.ucfg)), uj)

    def member_u(self, eng: InferenceEngine, res: dict) -> np.ndarray:
        """Eq. 2-4 difficulty of a member generation restricted to the
        answer span (``answer_mask``).  This is the u the streaming serve
        path reports — a request retires at the stop token, so its
        accumulated terms never include post-answer steps — and the
        batched path must score the same way for the two to agree."""
        if self.stop_token is None or res.get("logits") is None:
            return res["u"]
        mask = answer_mask(np.asarray(res["tokens"], np.int32),
                           self.stop_token)
        return np.asarray(U.difficulty(res["logits"],
                                       jnp.asarray(res["tokens"]),
                                       eng.ucfg, mask=jnp.asarray(mask)))
