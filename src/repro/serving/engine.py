"""Inference engine: cached autoregressive generation + integrated probe.

The uncertainty probe (paper Sec. IV-B) is computed *inside* the serving
loop from the logits the engine already produces — on TPU via the fused
``swarm_uncertainty`` kernel — so difficulty estimation adds no extra
forward pass: the paper's probe SLM "is" the local SLM.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.uncertainty import UncertaintyConfig, difficulty
from repro.models import transformer as T
from repro.models.common import ModelConfig

Array = jax.Array


@partial(jax.jit, static_argnames=("cfg", "greedy"))
def _step(params, cfg: ModelConfig, tokens, cache, index, rng, greedy: bool):
    logits, cache = T.decode_step(params, cfg, tokens, cache, index)
    lg = logits[:, -1].astype(jnp.float32)
    if greedy:
        nxt = jnp.argmax(lg, axis=-1)
    else:
        nxt = jax.random.categorical(rng, lg, axis=-1)
    return nxt.astype(jnp.int32), lg, cache


@dataclasses.dataclass
class InferenceEngine:
    """One swarm member: a model + its decode state machinery."""
    name: str
    cfg: ModelConfig
    params: Any
    ucfg: UncertaintyConfig = dataclasses.field(default_factory=UncertaintyConfig)
    max_len: int = 128

    def generate(self, prompts: np.ndarray, max_new: int, *,
                 greedy: bool = True, seed: int = 0) -> dict:
        """prompts (B, S) int32, LEFT-padded with PAD=0 (HF batched-decode
        convention, so the last absorbed position is always the prompt end).
        The prompt is absorbed teacher-forced through the cached decode
        path; generated-token logits feed the Eq. 2-4 difficulty score.
        """
        prompts = np.asarray(prompts, np.int32)
        B, S = prompts.shape
        cache = T.init_cache(self.cfg, B, self.max_len)
        cache = jax.tree.map(jnp.asarray, cache)
        rng = jax.random.PRNGKey(seed)

        lengths = (prompts != 0).sum(axis=1)      # PAD=0
        nxt = None
        # teacher-forced prompt absorption (static positions; PAD slots are
        # overwritten later by real tokens for shorter prompts)
        for t in range(S):
            tok = jnp.asarray(prompts[:, t:t + 1])
            nxt, last_logits, cache = _step(
                self.params, self.cfg, tok, cache,
                jnp.full((B,), t, jnp.int32), rng, True)

        out_tokens = []
        out_logits = []
        cur = nxt
        for n in range(max_new):
            out_tokens.append(cur)
            out_logits.append(last_logits)
            rng, sub = jax.random.split(rng)
            cur, last_logits, cache = _step(
                self.params, self.cfg, cur[:, None], cache,
                jnp.full((B,), S + n, jnp.int32), sub, greedy)

        tokens = jnp.stack(out_tokens, axis=1)              # (B, N)
        logits = jnp.stack(out_logits, axis=1)              # (B, N, V)
        u = difficulty(logits, tokens, self.ucfg)           # (B,)
        return {"tokens": np.asarray(tokens),
                "u": np.asarray(u),
                "logits": logits,
                "prompt_lengths": np.asarray(lengths)}

    def token_count(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        return (np.asarray(prompts) != 0).sum(axis=1) + max_new
