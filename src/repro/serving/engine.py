"""Inference engine: two-phase serving runtime + integrated probe.

The runtime is the paper's edge hot path (Sec. VI latency) restructured the
way production servers run it:

  * **prefill** — the whole prompt is absorbed in ONE jitted pass
    (``transformer.prefill``) that bulk-fills every layer cache, instead of
    S sequential ``decode_step`` dispatches;
  * **decode** — ``max_new`` steps run as a single ``lax.scan``; a full
    ``generate`` fuses prefill + scan + probe into ONE device call;
  * **continuous batching** — ``serve()`` streams requests through the
    vLLM-style ``ContinuousBatcher``: admit into free slots, prefill the
    slot, scan-decode over all slots, retire at stop token / max_new.

Prompt shapes are bucketed (left-padded to the next power of two) so
heterogeneous batches hit a handful of compilations; bucket padding uses
negative positions, which every mixer's prefill treats as inert, so bucketed
results are bitwise-identical to unbucketed ones.

The uncertainty probe (paper Sec. IV-B) is computed *inside* the decode scan
from the logits the engine already produces — difficulty estimation adds no
extra forward pass: the paper's probe SLM "is" the local SLM.

With a ``(data, model)`` mesh attached (``launch/mesh.py::serving_mesh``)
every phase runs SPMD-partitioned: parameters placed by the logical-axis
rules, caches and batch dims sharded on 'data', jitted entry points built
with explicit in/out shardings (docs/SHARDING.md).  Greedy tokens are
bitwise-identical to the single-device path.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import uncertainty as U
from repro.core.uncertainty import UncertaintyConfig
from repro.distributed import sharding as sh
from repro.models import transformer as T
from repro.models.common import ModelConfig
from repro.serving.cache_manager import EvictedSessionError, PagedHandle
from repro.serving.faults import FaultPlan, PoolExhaustedError
from repro.serving.scheduler import ContinuousBatcher, Request

Array = jax.Array

PAD = 0


def bucket_len(s: int, granularity: int = 512, floor: int = 8) -> int:
    """Shape bucket for prompt lengths: next power of two up to
    ``granularity``, then multiples of ``2 * granularity`` (keeps the
    chunked-attention / SSD block-divisibility asserts satisfied)."""
    if s <= floor:
        return floor
    if s <= granularity:
        return 1 << (s - 1).bit_length()
    g2 = 2 * granularity
    return -(-s // g2) * g2


# ---------------------------------------------------------------------------
# Jitted phases
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "mesh", "rules"))
def _prefill_into(params, cfg: ModelConfig, prompts, s_orig, cache,
                  mesh=None, rules=None):
    """Cold prefill into a provided cache — a fresh monolithic cache or a
    paged cache whose blocks the pool just reset (identical contents, so
    the two entry points share one implementation).  prompts (B, Sb)
    left-padded to a bucket; s_orig = pre-bucket length.  Returns (first
    greedy token (B,), its logits (B,V) f32, filled cache).

    On-mesh (mesh + rules static args set) the cache is pinned to its
    logical-axis sharding before the prefill fills it, so the bulk KV
    scatter and the carried recurrent states come out sharded.
    """
    B, S = prompts.shape
    cache = T.constrain_cache(cache, cfg, mesh, rules)
    # columns left of the original padded prompt get negative positions and
    # are inert in every mixer; real columns keep positions 0..s_orig-1
    positions = jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[None] - (S - s_orig), (B, S))
    logits, cache = T.prefill(params, cfg, prompts, cache, positions,
                              mesh=mesh, rules=rules)
    last = logits[:, -1].astype(jnp.float32)
    last = sh.constrain(last, ("act_batch", "act_vocab"), mesh, rules)
    cur = jnp.argmax(last, axis=-1).astype(jnp.int32)
    return cur, last, cache


@partial(jax.jit, static_argnames=("cfg", "max_len", "mesh", "rules"))
def _prefill_absorb(params, cfg: ModelConfig, prompts, s_orig, max_len: int,
                    mesh=None, rules=None):
    """Monolithic cold prefill: initialise a (B, max_len) cache and absorb
    the prompt into it (see ``_prefill_into``)."""
    B = prompts.shape[0]
    return _prefill_into(params, cfg, prompts, s_orig,
                         T.init_cache(cfg, B, max_len), mesh=mesh, rules=rules)


@partial(jax.jit, static_argnames=("cfg", "ucfg", "steps", "greedy",
                                   "with_logits", "mesh", "rules"))
def _decode_scan(params, cfg: ModelConfig, cur, last, cache, pos, rng,
                 ucfg: UncertaintyConfig, steps: int, greedy: bool,
                 with_logits: bool = True, mesh=None, rules=None):
    """``steps`` decode iterations as one lax.scan.

    cur (B,) token entering the span; last (B,V) its logits; pos (B,) its
    absolute position.  Emits the tokens/logits *entering* each step (so the
    first emitted token is the prefill argmax, matching the legacy stepwise
    loop) plus the per-position Eq. 2-3 uncertainty terms.  The streaming
    serve path passes with_logits=False so the (B, steps, V) stack is never
    materialised as a jit output.

    On-mesh, the per-step logits are pinned ``(act_batch, act_vocab)`` and
    every cache/state leaf is re-constrained inside the mixers, so the scan
    carry keeps its sharding across all ``steps`` instead of collapsing to
    whatever layout GSPMD infers for the loop body.
    """
    def body(carry, _):
        cur, last, cache, pos, rng = carry
        # Eq. 2-3 terms of the *emitted* token: cur was chosen from last
        h, v = U.uncertainty_terms(last[:, None, :], cur[:, None], ucfg)
        rng, sub = jax.random.split(rng)
        logits, cache = T.decode_step(params, cfg, cur[:, None], cache, pos,
                                      mesh=mesh, rules=rules)
        lg = logits[:, -1].astype(jnp.float32)
        lg = sh.constrain(lg, ("act_batch", "act_vocab"), mesh, rules)
        if greedy:
            nxt = jnp.argmax(lg, axis=-1)
        else:
            nxt = jax.random.categorical(sub, lg, axis=-1)
        out = (cur, h[:, 0], v[:, 0]) + ((last,) if with_logits else ())
        return (nxt.astype(jnp.int32), lg, cache, pos + 1, rng), out

    carry, outs = jax.lax.scan(body, (cur, last, cache, pos, rng),
                               length=steps)
    toks, h_per, v_per = (o.swapaxes(0, 1) for o in outs[:3])
    lgs = outs[3].swapaxes(0, 1) if with_logits else None
    return toks, lgs, h_per, v_per, carry


@partial(jax.jit, static_argnames=("cfg", "ucfg", "max_new", "max_len",
                                   "greedy", "mesh", "rules"))
def _generate_fused(params, cfg: ModelConfig, prompts, s_orig, rng,
                    ucfg: UncertaintyConfig, max_new: int, max_len: int,
                    greedy: bool, mesh=None, rules=None):
    """Whole generation — prefill, scanned decode and the Eq. 4 combine —
    as ONE device call (nested jits trace inline).

    Returns (tokens, logits, u, h_mean, v_mean, carry): the raw Eq. 2-3
    per-request means let callers re-average u over an extended generation,
    and the decode-scan carry (cur, last, cache, pos, rng) is the warm
    session state ``InferenceEngine.generate(..., return_state=True)``
    hands out."""
    B = prompts.shape[0]
    cur, last, cache = _prefill_absorb(params, cfg, prompts, s_orig, max_len,
                                       mesh=mesh, rules=rules)
    toks, lgs, h_per, v_per, carry = _decode_scan(
        params, cfg, cur, last, cache, jnp.broadcast_to(s_orig, (B,)), rng,
        ucfg, max_new, greedy, mesh=mesh, rules=rules)
    h, v = h_per.mean(-1), v_per.mean(-1)
    return toks, lgs, U.combine_terms(h, v, ucfg), h, v, carry


# ---------------------------------------------------------------------------
# Paged entry points: gather the slot-linear view of the block pool, run the
# UNCHANGED monolithic bodies on it, scatter only the written block range
# back (transformer.paged_gather / paged_scatter_back).  Three consequences:
#   * bitwise parity with the monolithic path by construction (the same
#     compiled math runs on an elementwise-equal cache);
#   * the decode-scan carry stays shape-stable and O(B * max_len) — the
#     pool never rides the carry (that costs O(pool) per step: XLA
#     re-materialises scan carries, measured 10x on the smoke decode);
#   * pool writes are O(tokens written), so refcount-shared prefix blocks
#     are physically never touched (the COW invariant).
# The pool arrays are DONATED into each dispatch — the engine commits the
# returned arrays to the CachePool immediately, so the old buffers are
# dead; on backends with donation support the scatter-back aliases in
# place instead of copying the pool.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "mesh", "rules"),
         donate_argnames=("cache",))
def _prefill_into_paged(params, cfg: ModelConfig, prompts, s_orig, cache,
                        mesh=None, rules=None):
    """Paged cold prefill: gather -> ``_prefill_into`` -> scatter blocks
    [0, s_orig) back.  Returns (cur, last, updated paged cache)."""
    B = prompts.shape[0]
    cache = T.constrain_cache(cache, cfg, mesh, rules)
    lin = T.paged_gather(cfg, cache)
    cur, last, lin = _prefill_into(params, cfg, prompts, s_orig, lin,
                                   mesh=mesh, rules=rules)
    layers = T.paged_scatter_back(
        cfg, cache, lin, jnp.zeros((B,), jnp.int32),
        jnp.broadcast_to(s_orig, (B,)).astype(jnp.int32))
    return cur, last, T.paged_cache(layers, cache["table"], cache["rows"])


@partial(jax.jit, static_argnames=("cfg", "mesh", "rules"),
         donate_argnames=("cache",))
def _prefill_continue_paged(params, cfg: ModelConfig, prompts, s_orig, start,
                            cache, mesh=None, rules=None):
    """Paged continuation prefill: gather -> ``_prefill_continue`` ->
    scatter blocks [start, start + s_orig) back."""
    cache = T.constrain_cache(cache, cfg, mesh, rules)
    lin = T.paged_gather(cfg, cache)
    cur, last, lin = _prefill_continue(params, cfg, prompts, s_orig, start,
                                       lin, mesh=mesh, rules=rules)
    layers = T.paged_scatter_back(cfg, cache, lin, start, start + s_orig)
    return cur, last, T.paged_cache(layers, cache["table"], cache["rows"])


def _decode_scan_kernel(params, cfg: ModelConfig, cur, last, cache, pos, rng,
                        ucfg: UncertaintyConfig, steps: int, greedy: bool,
                        with_logits: bool = True, mesh=None, rules=None):
    """Kernel-first paged decode chunk: attention reads KV blocks IN PLACE
    through the block table (``transformer.paged_decode_step``) — the
    O(B * S) slot-linear view is never materialised.  The scan carry holds
    only the O(B * steps) delta write buffers + O(B) recurrent state rows
    (``paged_decode_carry``); the pool rides the closure as a scan constant
    and receives one delta scatter at the end of the dispatch.  Sampling,
    rng-splitting and uncertainty ops mirror ``_decode_scan`` exactly, and
    the streamed chunk data equals the gathered view elementwise, so tokens
    AND logits are bitwise-identical to the gathered-view path."""
    cache = T.constrain_cache(cache, cfg, mesh, rules)
    p0 = pos
    delta0 = T.paged_decode_carry(cfg, cache, steps)

    def body(carry, t):
        cur, last, delta, pos_c, rng = carry
        h, v = U.uncertainty_terms(last[:, None, :], cur[:, None], ucfg)
        rng, sub = jax.random.split(rng)
        logits, delta = T.paged_decode_step(
            params, cfg, cur[:, None], cache, delta, pos_c, t, p0,
            mesh=mesh, rules=rules)
        lg = logits[:, -1].astype(jnp.float32)
        lg = sh.constrain(lg, ("act_batch", "act_vocab"), mesh, rules)
        if greedy:
            nxt = jnp.argmax(lg, axis=-1)
        else:
            nxt = jax.random.categorical(sub, lg, axis=-1)
        out = (cur, h[:, 0], v[:, 0]) + ((last,) if with_logits else ())
        return (nxt.astype(jnp.int32), lg, delta, pos_c + 1, rng), out

    carry, outs = jax.lax.scan(body, (cur, last, delta0, pos, rng),
                               jnp.arange(steps))
    cur2, last2, delta2, pos2, rng2 = carry
    layers = T.paged_scatter_decode(cfg, cache, delta2, p0)
    out_cache = T.paged_cache(layers, cache["table"], cache["rows"])
    toks, h_per, v_per = (o.swapaxes(0, 1) for o in outs[:3])
    lgs = outs[3].swapaxes(0, 1) if with_logits else None
    return toks, lgs, h_per, v_per, (cur2, last2, out_cache, pos2, rng2)


@partial(jax.jit, static_argnames=("cfg", "ucfg", "steps", "greedy",
                                   "with_logits", "impl", "mesh", "rules"),
         donate_argnames=("cache",))
def _decode_scan_paged(params, cfg: ModelConfig, cur, last, cache, pos, rng,
                       ucfg: UncertaintyConfig, steps: int, greedy: bool,
                       with_logits: bool = True, impl: str = "gather",
                       mesh=None, rules=None):
    """Paged decode chunk.  ``impl="kernel"`` (the serving default) runs the
    kernel-first in-place block-table path (``_decode_scan_kernel``);
    ``impl="gather"`` is the parity oracle: gather -> the monolithic
    ``_decode_scan`` -> scatter blocks [pos, pos + steps) back.  Carry
    mirrors ``_decode_scan`` with the paged cache pytree in the cache
    slot; both impls produce bitwise-identical tokens and logits."""
    if impl == "kernel":
        return _decode_scan_kernel(params, cfg, cur, last, cache, pos, rng,
                                   ucfg, steps, greedy,
                                   with_logits=with_logits, mesh=mesh,
                                   rules=rules)
    cache = T.constrain_cache(cache, cfg, mesh, rules)
    lin = T.paged_gather(cfg, cache)
    toks, lgs, h_per, v_per, carry = _decode_scan(
        params, cfg, cur, last, lin, pos, rng, ucfg, steps, greedy,
        with_logits=with_logits, mesh=mesh, rules=rules)
    cur2, last2, lin2, pos2, rng2 = carry
    layers = T.paged_scatter_back(cfg, cache, lin2, pos, pos + steps)
    out_cache = T.paged_cache(layers, cache["table"], cache["rows"])
    return toks, lgs, h_per, v_per, (cur2, last2, out_cache, pos2, rng2)


@partial(jax.jit, static_argnames=("cfg", "ucfg", "max_new", "greedy",
                                   "impl", "mesh", "rules"),
         donate_argnames=("cache",))
def _generate_fused_paged(params, cfg: ModelConfig, prompts, s_orig, cache,
                          rng, ucfg: UncertaintyConfig, max_new: int,
                          greedy: bool, impl: str = "gather", mesh=None,
                          rules=None):
    """Paged sibling of ``_generate_fused``: the cache comes in as the
    paged pool + this request's block tables / state rows (freshly
    allocated and reset by the CachePool) instead of being initialised
    in-trace.

    ``impl="gather"``: one gather, the whole monolithic prefill + scanned
    decode, one scatter of blocks [0, s_orig + max_new).
    ``impl="kernel"``: the prefill still gathers (amortised over the whole
    span) and scatters [0, s_orig) back, but the decode scan reads blocks
    in place (``_decode_scan_kernel``) — no per-step slot-linear KV."""
    B = prompts.shape[0]
    cache = T.constrain_cache(cache, cfg, mesh, rules)
    lin = T.paged_gather(cfg, cache)
    cur, last, lin = _prefill_into(params, cfg, prompts, s_orig, lin,
                                   mesh=mesh, rules=rules)
    pos = jnp.broadcast_to(s_orig, (B,))
    if impl == "kernel":
        layers = T.paged_scatter_back(
            cfg, cache, lin, jnp.zeros((B,), jnp.int32),
            jnp.broadcast_to(s_orig, (B,)).astype(jnp.int32))
        cache = T.paged_cache(layers, cache["table"], cache["rows"])
        toks, lgs, h_per, v_per, carry = _decode_scan_kernel(
            params, cfg, cur, last, cache, pos, rng, ucfg, max_new, greedy,
            mesh=mesh, rules=rules)
    else:
        toks, lgs, h_per, v_per, scarry = _decode_scan(
            params, cfg, cur, last, lin, pos, rng,
            ucfg, max_new, greedy, mesh=mesh, rules=rules)
        cur2, last2, lin2, pos2, rng2 = scarry
        layers = T.paged_scatter_back(
            cfg, cache, lin2, jnp.zeros((B,), jnp.int32),
            jnp.broadcast_to(s_orig + max_new, (B,)).astype(jnp.int32))
        out_cache = T.paged_cache(layers, cache["table"], cache["rows"])
        carry = (cur2, last2, out_cache, pos2, rng2)
    h, v = h_per.mean(-1), v_per.mean(-1)
    return toks, lgs, U.combine_terms(h, v, ucfg), h, v, carry


@partial(jax.jit, static_argnames=("cfg", "ucfg", "max_new", "greedy",
                                   "impl", "mesh", "rules"),
         donate_argnames=("cache",))
def _generate_continue_paged(params, cfg: ModelConfig, prompts, s_orig,
                             start, cache, rng, ucfg: UncertaintyConfig,
                             max_new: int, greedy: bool,
                             impl: str = "gather", mesh=None, rules=None):
    """Paged sibling of ``_generate_continue``: continuation prefill +
    scanned decode, scatter of blocks [start, start + s_orig + max_new).
    ``impl="kernel"`` scatters the prefill span back and decodes in place
    through the block table (see ``_generate_fused_paged``)."""
    cache = T.constrain_cache(cache, cfg, mesh, rules)
    lin = T.paged_gather(cfg, cache)
    cur, last, lin = _prefill_continue(params, cfg, prompts, s_orig, start,
                                       lin, mesh=mesh, rules=rules)
    if impl == "kernel":
        layers = T.paged_scatter_back(cfg, cache, lin, start, start + s_orig)
        cache = T.paged_cache(layers, cache["table"], cache["rows"])
        toks, lgs, h_per, v_per, carry = _decode_scan_kernel(
            params, cfg, cur, last, cache, start + s_orig, rng, ucfg,
            max_new, greedy, mesh=mesh, rules=rules)
    else:
        toks, lgs, h_per, v_per, scarry = _decode_scan(
            params, cfg, cur, last, lin, start + s_orig, rng, ucfg, max_new,
            greedy, mesh=mesh, rules=rules)
        cur2, last2, lin2, pos2, rng2 = scarry
        layers = T.paged_scatter_back(cfg, cache, lin2, start,
                                      start + s_orig + max_new)
        out_cache = T.paged_cache(layers, cache["table"], cache["rows"])
        carry = (cur2, last2, out_cache, pos2, rng2)
    h, v = h_per.mean(-1), v_per.mean(-1)
    return toks, lgs, U.combine_terms(h, v, ucfg), h, v, carry


@partial(jax.jit, static_argnames=("cfg", "mesh", "rules"))
def _prefill_continue(params, cfg: ModelConfig, prompts, s_orig, start,
                      cache, mesh=None, rules=None):
    """Continuation prefill: absorb a new span into an already-populated
    cache.  prompts (B, Sb) RIGHT-padded to a bucket (real tokens first, so
    the recurrent conv windows cross from the cached context tail straight
    into the span); s_orig = pre-bucket span length; start (B,) the
    session's next absolute position.  Returns (first greedy token (B,),
    its logits (B,V) f32, the updated cache).

    On-mesh the incoming warm cache is re-pinned to its logical-axis
    sharding before the span is spliced in, so a cache handed across jit
    boundaries keeps the ``cache_axes`` placement of docs/SHARDING.md.
    """
    B, S = prompts.shape
    col = jnp.arange(S, dtype=jnp.int32)[None]
    # real columns at absolute positions start..start+s_orig-1; bucket
    # padding keeps negative positions => inert in every mixer
    positions = jnp.where(col < s_orig, start[:, None] + col, col - S)
    cache = T.constrain_cache(cache, cfg, mesh, rules)
    logits, cache = T.prefill(params, cfg, prompts, cache, positions,
                              continuation=True, mesh=mesh, rules=rules)
    last = jax.lax.dynamic_slice_in_dim(logits, s_orig - 1, 1, axis=1)
    last = last[:, 0].astype(jnp.float32)
    last = sh.constrain(last, ("act_batch", "act_vocab"), mesh, rules)
    cur = jnp.argmax(last, axis=-1).astype(jnp.int32)
    return cur, last, cache


@partial(jax.jit, static_argnames=("cfg", "ucfg", "max_new", "greedy",
                                   "mesh", "rules"))
def _generate_continue(params, cfg: ModelConfig, prompts, s_orig, start,
                       cache, rng, ucfg: UncertaintyConfig, max_new: int,
                       greedy: bool, mesh=None, rules=None):
    """Warm-path sibling of ``_generate_fused``: continuation prefill over a
    live cache + scanned decode, one device call.  Same outputs."""
    cur, last, cache = _prefill_continue(params, cfg, prompts, s_orig, start,
                                         cache, mesh=mesh, rules=rules)
    toks, lgs, h_per, v_per, carry = _decode_scan(
        params, cfg, cur, last, cache, start + s_orig, rng,
        ucfg, max_new, greedy, mesh=mesh, rules=rules)
    h, v = h_per.mean(-1), v_per.mean(-1)
    return toks, lgs, U.combine_terms(h, v, ucfg), h, v, carry


@partial(jax.jit, static_argnames=("cfg", "greedy", "mesh", "rules"))
def _step(params, cfg: ModelConfig, tokens, cache, index, rng, greedy: bool,
          mesh=None, rules=None):
    logits, cache = T.decode_step(params, cfg, tokens, cache, index,
                                  mesh=mesh, rules=rules)
    lg = logits[:, -1].astype(jnp.float32)
    if greedy:
        nxt = jnp.argmax(lg, axis=-1)
    else:
        nxt = jax.random.categorical(rng, lg, axis=-1)
    return nxt.astype(jnp.int32), lg, cache


@dataclasses.dataclass
class SessionState:
    """Warm cache handle: everything needed to continue a generation.

    Returned by ``InferenceEngine.generate(..., return_state=True)`` and by
    ``serve()`` for requests submitted with ``return_state=True``; accepted
    back by ``generate(..., state=...)`` and by warm ``serve()`` admissions
    (``Request.state``).  The handle is engine-specific — caches encode one
    model's layer plan and dtypes — and single-use by convention: continuing
    mutates nothing (JAX arrays are immutable) but the positions only make
    sense along one timeline, so fork via ``state_select`` if needed.

    * ``cache`` — layer-cache pytree (see ``transformer.init_cache``),
      populated through position ``pos - 1``.  On-mesh it carries the
      ``cache_axes`` shardings of docs/SHARDING.md.
    * ``pos`` (B,) int32 — next absolute write position per row.
    * ``cur`` (B,) int32 — the last sampled token, not yet absorbed or
      emitted (the decode scan's pending token): pure decode extension
      (``generate(None, k, state=...)``) resumes from it bitwise.
    * ``last`` (B,V) f32 — ``cur``'s logits.
    * ``max_len`` — static cache length (slots).
    * ``offset`` — host-side upper bound of ``pos`` (static int), used to
      size cache growth without a device sync.
    * ``rng`` — the decode scan's carried PRNG key (None when unavailable,
      e.g. serve()-extracted states whose sampling stream was shared
      across slots).  Pure decode extension resumes from it, so sampled
      (greedy=False) extension replays a longer generation bitwise too.
    * ``exact`` — False when the handle was captured off a slot that kept
      decoding past the request's stop token (mid-chunk retirement): the
      KV entries up to ``pos`` are still exact, but the pending
      ``cur``/``last`` and any recurrent-mixer state have absorbed
      post-stop garbage steps.  Such a handle only supports continuation
      prefill on attention-only models; anything else raises.

    On a paged engine (``InferenceEngine(paged=True)``), ``cache`` is not
    an array pytree but a :class:`~repro.serving.cache_manager.PagedHandle`
    — the session's block tables, state-row ids and the pool epoch.  The
    handle references pool storage by id, so it is O(table) on host memory,
    fan-out (``state_select`` / ``engine.fanout``) is a refcounted table
    copy, and growth appends blocks instead of copying the cache;
    ``max_len`` stays the *logical* capacity (what the monolithic engine
    would carry), which keeps paged dispatch shapes — and therefore
    numerics — bitwise-identical to the monolithic path even after the
    pool trims the physical tables to the covered length.  Paged handles
    stay registered with the pool until ``engine.release(state)`` or TTL
    eviction; reuse after that raises ``EvictedSessionError``.

    Unlike monolithic states (immutable array pytrees), a paged handle is
    a LIVE reference into the pool: continuing or extending it writes its
    blocks and state rows in place.  The single-use convention is
    therefore load-bearing on paged recurrent-mixer engines — extending
    the same handle twice gathers post-extension state rows the second
    time.  (On attention-only models a repeated greedy extension rewrites
    identical K/V, so benchmark-style reuse stays exact.)  Fork with
    ``state_select`` / ``fanout`` before extending if you need both
    timelines.
    """
    cache: Any
    pos: Any
    cur: Any
    last: Any
    max_len: int
    offset: int
    rng: Any = None
    exact: bool = True

    @property
    def batch(self) -> int:
        return int(self.pos.shape[0])


@dataclasses.dataclass
class InferenceEngine:
    """One swarm member: a model + its two-phase serving runtime.

    ``mesh`` (optional, from ``launch/mesh.py``) turns on the mesh-sharded
    runtime: parameters are placed by the logical-axis ``rules`` (default
    ``SERVE_RULES`` — weights replicated over 'data', tensor-parallel over
    'model'), the KV/recurrent caches and every batch dimension shard over
    'data', and the jitted prefill / scanned decode run with explicit in/out
    shardings so XLA partitions one program across the mesh.  Greedy tokens
    are the same as the single-device path; ``mesh=None`` (default) is
    bit-for-bit the unsharded engine.
    """
    name: str
    cfg: ModelConfig
    params: Any
    ucfg: UncertaintyConfig = dataclasses.field(default_factory=UncertaintyConfig)
    max_len: int = 128
    mesh: Any = None                    # jax.sharding.Mesh with (data, model)
    rules: Any = None                   # ShardingRules; default SERVE_RULES
    # paged block-pool cache manager (docs/RUNTIME.md "Paged caches &
    # prefix sharing"): KV lives in a fixed pool of block_len-sized blocks
    # addressed through per-slot block tables, session growth appends
    # blocks instead of grow_cache's whole-buffer copy, and absorbed
    # prefixes fan out to many slots copy-on-write.  Bitwise-identical to
    # the monolithic path (the gathered table view equals the monolithic
    # cache elementwise) as long as block_len divides the engine's cache
    # bucketing — the default 64 always does.
    paged: bool = False
    block_len: int = 64
    pool_blocks: int | None = None      # default: 16 full-length sessions
    pool_rows: int | None = None        # recurrent-state rows in the pool
    # paged decode-attention impl (docs/RUNTIME.md "Kernel-first decode"):
    # "kernel" reads KV blocks in place through the block table — no
    # per-step slot-linear gather; "gather" is the parity oracle (gather ->
    # monolithic decode -> scatter).  None = measured-best per backend
    # (kernel everywhere: bitwise-identical tokens+logits either way, and
    # the in-place read wins on both CPU and TPU — see benchmarks/
    # decode_microbench.py).
    attn_decode_impl: str | None = None
    # quantized serving (docs/RUNTIME.md "Quantized caches"):
    # ``cache_quant`` stores the paged pool's KV blocks int8/fp8 with
    # per-row f32 scales (requires paged=True; recurrent state rows stay
    # bf16) — correctness becomes *budgeted*: greedy tokens match bf16 on
    # the smoke workloads and logit error stays within the per-arch
    # budget, instead of bitwise.  ``weight_quant`` stores the serving
    # matmul weights (attention/MLP/MoE projections + untied lm_head) as
    # QTensors, dequantized on the fly at the matmul call sites; works on
    # monolithic and paged engines, on- and off-mesh.
    cache_quant: str | None = None
    weight_quant: str | None = None
    # persistent compilation cache: set to a directory to make every jit
    # this engine triggers write/read XLA executables there — a second
    # process constructing the same engine performs ZERO fresh compiles
    # for already-seen (config, bucket, mesh) cells (serve() cold start).
    compilation_cache_dir: str | None = None

    def __post_init__(self):
        if self.compilation_cache_dir is not None:
            jax.config.update("jax_compilation_cache_dir",
                              self.compilation_cache_dir)
            # cache every executable, however small/fast to compile —
            # serve() cold-start cost is dominated by many small jits
            jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
            jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
            # any jit BEFORE this point (param init, another engine) latches
            # the cache module into "initialized, disabled" — re-arm it so
            # it picks up the directory we just configured
            from jax.experimental.compilation_cache import compilation_cache
            compilation_cache.reset_cache()
        self._mesh_jits: dict = {}
        # host-side dispatch accounting: how many cold prefills, warm
        # continuation prefills and decode-only resumes this engine issued
        # (the gateway tests assert the probe's swarm round adds zero here),
        # plus grow_copy — whole-cache growth copies, always 0 when paged
        self.counters = {"prefill": 0, "prefill_continue": 0,
                         "decode_only": 0, "grow_copy": 0,
                         # failure-domain accounting (docs/RUNTIME.md
                         # "Failure semantics"): admission rounds deferred
                         # by famine backpressure, requests shed/expired,
                         # slot-failure requeues, and transparent cold
                         # re-prefills after a warm handle was evicted
                         "famine_deferred": 0, "shed": 0, "expired": 0,
                         "requeued": 0, "reprefill_cold": 0}
        # warm continuation attends CHUNKED over the cache, which needs the
        # cache length divisible by the KV block once it exceeds one block
        # (cold prefill/decode never hit this: they chunk only the span)
        kvb = self.cfg.attn_kv_block
        if self.max_len > kvb and self.max_len % kvb:
            self.max_len = -(-self.max_len // kvb) * kvb
        self._recurrent = any(m in ("rglru", "ssd")
                              for m, _ in self.cfg.layer_plan())
        from repro.models import quant as Q
        Q.check_quant(self.cache_quant)
        Q.check_quant(self.weight_quant)
        if self.cache_quant is not None and not self.paged:
            raise ValueError(
                "cache_quant requires paged=True: quantization is per pool "
                "block (scales ride the block pool as a sidecar leaf); the "
                "monolithic cache stays bf16")
        if self.weight_quant is not None:
            self.params = Q.quantize_params(self.params, self.weight_quant)
        self.pool = None
        if self.paged:
            L = self.block_len
            if self.max_len % L:
                # whole-block tables AND kv-chunk divisibility (lcm)
                self.max_len = self._round_len(self.max_len)
            has_local = any(m == "attn_local"
                            for m, _ in self.cfg.layer_plan())
            if has_local and self.cfg.window is not None \
                    and self.cfg.window % L:
                raise ValueError(
                    f"paged cache: block_len={L} must divide the local-"
                    f"attention window {self.cfg.window} (the ring view is "
                    "assembled from whole blocks)")
            from repro.serving.cache_manager import CachePool
            if self.attn_decode_impl is None:
                self.attn_decode_impl = "kernel"
            if self.attn_decode_impl not in ("kernel", "gather"):
                raise ValueError(
                    f"attn_decode_impl must be 'kernel' or 'gather', got "
                    f"{self.attn_decode_impl!r}")
            n_blocks = self.pool_blocks or max(64, 16 * self.max_len // L)
            n_rows = self.pool_rows or max(
                16, n_blocks * L // max(self.max_len, 1))
            self.rules = self.rules or (sh.SERVE_RULES
                                        if self.mesh is not None else None)
            self.pool = CachePool(self.cfg, L, n_blocks, n_rows,
                                  cache_quant=self.cache_quant,
                                  mesh=self.mesh, rules=self.rules)
        if self.mesh is None:
            return
        self.rules = self.rules or sh.SERVE_RULES
        # explicit parameter placement: the logical-axis rules decide which
        # dims shard ('heads'/'ffn'/'vocab' over 'model'); the rest replicate.
        # Quantized weights mirror the axes tree over the QTensor leaves so
        # each payload row and its scale land on the same shard.
        axes = T.param_axes(self.cfg)
        if self.weight_quant is not None:
            axes = Q.quantize_param_axes(axes, self.params)
        self._param_sh = sh.tree_shardings(
            self.params, axes, self.mesh, self.rules)
        self.params = jax.device_put(self.params, self._param_sh)

    # ------------------------------------------------------------------
    # Sharded entry points (built lazily, cached per shape signature)
    # ------------------------------------------------------------------

    def _act_sh(self, shape, logical):
        return NamedSharding(self.mesh, sh.spec_for(
            shape, logical, self.mesh, self.rules.act_rules))

    def _cache_sh(self, cache_or_avals):
        specs = sh.tree_specs(cache_or_avals, T.cache_axes(self.cfg),
                              self.mesh, self.rules.act_rules)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs)

    def _fused_sharded(self, B: int, Sb: int, max_len: int, max_new: int,
                       greedy: bool):
        """jitted prefill+decode with explicit in/out shardings: params by
        rule, prompts/tokens/u sharded on 'data' (batch), logits on
        ('data' batch x 'model' vocab)."""
        key = ("fused", B, Sb, max_len, max_new, greedy)
        fn = self._mesh_jits.get(key)
        if fn is None:
            cfg, ucfg, mesh, rules = self.cfg, self.ucfg, self.mesh, self.rules

            def body(params, prompts, s_orig, rng):
                return _generate_fused(params, cfg, prompts, s_orig, rng,
                                       ucfg, max_new, max_len, greedy,
                                       mesh=mesh, rules=rules)

            rep = NamedSharding(mesh, P())
            fn = jax.jit(
                body,
                in_shardings=(self._param_sh,
                              self._act_sh((B, Sb), ("act_batch", None)),
                              rep, rep),
                out_shardings=self._gen_out_sh(B, max_new, max_len))
            self._mesh_jits[key] = fn
        return fn

    def _gen_out_sh(self, B: int, max_new: int, max_len: int):
        """Output shardings shared by the fused cold and warm generate:
        (tokens, logits, u, h_mean, v_mean, carry) with the decode-scan
        carry — the session state — placed exactly like the decode chunk's
        slot state (cache per ``cache_axes``, batch dims on 'data')."""
        b_sh = self._act_sh((B,), ("act_batch",))
        v_sh = self._act_sh((B, self.cfg.vocab_size),
                            ("act_batch", "act_vocab"))
        csh = self._cache_sh(
            jax.eval_shape(lambda: T.init_cache(self.cfg, B, max_len)))
        rep = NamedSharding(self.mesh, P())
        return (self._act_sh((B, max_new), ("act_batch", None)),
                self._act_sh((B, max_new, self.cfg.vocab_size),
                             ("act_batch", None, "act_vocab")),
                b_sh, b_sh, b_sh,
                (b_sh, v_sh, csh, b_sh, rep))

    def _cont_sharded(self, B: int, Sb: int, max_len: int, max_new: int,
                      greedy: bool):
        """jitted continuation prefill + decode with explicit in/out
        shardings; the warm cache comes in already placed per cache_axes."""
        key = ("cont", B, Sb, max_len, max_new, greedy)
        fn = self._mesh_jits.get(key)
        if fn is None:
            cfg, ucfg, mesh, rules = self.cfg, self.ucfg, self.mesh, self.rules

            def body(params, prompts, s_orig, start, cache, rng):
                return _generate_continue(params, cfg, prompts, s_orig,
                                          start, cache, rng, ucfg, max_new,
                                          greedy, mesh=mesh, rules=rules)

            rep = NamedSharding(mesh, P())
            csh = self._cache_sh(
                jax.eval_shape(lambda: T.init_cache(cfg, B, max_len)))
            fn = jax.jit(
                body,
                in_shardings=(self._param_sh,
                              self._act_sh((B, Sb), ("act_batch", None)),
                              rep, self._act_sh((B,), ("act_batch",)),
                              csh, rep),
                out_shardings=self._gen_out_sh(B, max_new, max_len))
            self._mesh_jits[key] = fn
        return fn

    def _decode_sharded(self, B: int, max_len: int, steps: int, greedy: bool):
        """jitted decode chunk over the serve slots, explicit in/out
        shardings for the slot state (cur/last/pos/cache)."""
        key = ("decode", B, max_len, steps, greedy)
        fn = self._mesh_jits.get(key)
        if fn is None:
            cfg, ucfg, mesh, rules = self.cfg, self.ucfg, self.mesh, self.rules
            csh = self._cache_sh(
                jax.eval_shape(lambda: T.init_cache(cfg, B, max_len)))
            rep = NamedSharding(mesh, P())
            b_sh = self._act_sh((B,), ("act_batch",))
            v_sh = self._act_sh((B, cfg.vocab_size),
                                ("act_batch", "act_vocab"))
            n_sh = self._act_sh((B, steps), ("act_batch", None))

            def body(params, cur, last, cache, pos, rng):
                toks, _, h, v, carry = _decode_scan(
                    params, cfg, cur, last, cache, pos, rng, ucfg, steps,
                    greedy, with_logits=False, mesh=mesh, rules=rules)
                return toks, h, v, carry

            fn = jax.jit(
                body,
                in_shardings=(self._param_sh, b_sh, v_sh, csh, b_sh, rep),
                out_shardings=(n_sh, n_sh, n_sh,
                               (b_sh, v_sh, csh, b_sh, rep)))
            self._mesh_jits[key] = fn
        return fn

    # ------------------------------------------------------------------
    def _round_len(self, need: int) -> int:
        """Bucket a cache length: multiples of 64, and — because warm
        continuation attends chunked over the *cache* — multiples of
        ``attn_kv_block`` once the cache outgrows a single KV chunk.
        Paged engines round to the lcm with ``block_len`` so whole-block
        tables NEVER break the KV-chunk divisibility invariant (a
        block_len that divides 64/attn_kv_block — the default 64 does —
        leaves the lengths, and therefore numerics, identical to the
        monolithic path)."""
        g = math.lcm(64, self.block_len) if self.paged else 64
        n = -(-need // g) * g
        kvb = self.cfg.attn_kv_block
        if n > kvb:
            gk = math.lcm(kvb, self.block_len) if self.paged else kvb
            n = -(-n // gk) * gk
        return n

    def _cache_len(self, s_bucket: int, max_new: int) -> int:
        need = s_bucket + max_new
        if need <= self.max_len:
            return self.max_len
        return self._round_len(need)        # bucket cache growth too

    def _bucket(self, prompts: np.ndarray) -> tuple[np.ndarray, int]:
        B, S = prompts.shape
        gran = max(self.cfg.attn_q_block, self.cfg.attn_kv_block)
        Sb = bucket_len(S, gran)
        if Sb == S:
            return prompts, S
        out = np.zeros((B, Sb), np.int32)
        out[:, Sb - S:] = prompts
        return out, S

    def _bucket_right(self, prompts: np.ndarray) -> tuple[np.ndarray, int]:
        """Bucket a continuation span: RIGHT-padded, so no padding sits
        between the cached context and the new tokens (the recurrent conv
        windows must cross that boundary contiguously)."""
        B, S = prompts.shape
        gran = max(self.cfg.attn_q_block, self.cfg.attn_kv_block)
        Sb = bucket_len(S, gran)
        if Sb == S:
            return prompts, S
        out = np.zeros((B, Sb), np.int32)
        out[:, :S] = prompts
        return out, S

    def _grown_cache(self, state: SessionState, need: int):
        """(cache, max_len) with at least ``need`` slots, growing the
        session's cache (empty new slots) when it is too short.  Monolithic
        growth is ``grow_cache``'s whole-buffer copy (counted in
        ``counters["grow_copy"]``); the paged path never comes through here
        — it appends reset blocks to the block table instead."""
        if need <= state.max_len:
            return state.cache, state.max_len
        new_len = self._round_len(need)
        self.counters["grow_copy"] += 1
        cache = T.grow_cache(self.cfg, state.cache, state.batch, new_len)
        if self.mesh is not None:
            cache = jax.device_put(cache, self._cache_sh(cache))
        return cache, new_len

    # ------------------------------------------------------------------
    # Paged-cache helpers (CachePool-backed sessions)
    # ------------------------------------------------------------------

    def _paged_dev_cache(self, tables: np.ndarray, rows: np.ndarray):
        """The paged cache pytree for one dispatch: engine pool arrays +
        this dispatch's block tables and state-row ids."""
        return T.paged_cache(self.pool.arrays,
                             jnp.asarray(np.asarray(tables, np.int32)),
                             jnp.asarray(np.asarray(rows, np.int32)))

    def _paged_grown(self, state: SessionState, need: int):
        """Paged sibling of ``_grown_cache``: extend the session's block
        tables to the dispatch length (appending freshly reset blocks,
        COW-copying any shared block in the write range) — no whole-cache
        copy, ever.  Returns (cache pytree, dispatch max_len).  The
        dispatch length follows the same formula as the monolithic path so
        paged and monolithic dispatch shapes (and numerics) match."""
        handle = state.cache
        self.pool.check(handle)
        disp = state.max_len if need <= state.max_len \
            else self._round_len(need)
        tables = self.pool.extend(handle, disp // self.block_len,
                                  np.asarray(state.pos))
        return self._paged_dev_cache(tables, handle.rows), disp

    def release(self, state: SessionState) -> None:
        """Return a paged session's blocks to the pool and invalidate the
        handle (no-op for monolithic states — they are plain arrays)."""
        if self.paged and isinstance(state.cache, PagedHandle):
            self.pool.release(state.cache)

    def evict_idle_sessions(self, ttl_s: float) -> int:
        """TTL sweep over registered paged sessions (see CachePool)."""
        return self.pool.evict_idle(ttl_s) if self.paged else 0

    # ------------------------------------------------------------------
    # Session durability: checkpoint/restore through training/checkpoint
    # ------------------------------------------------------------------

    def checkpoint_session(self, state: SessionState, ckpt_dir: str, *,
                           step: int = 0, keep: int = 3) -> str:
        """Persist a session to disk so a chat survives an engine restart.

        Writes through :mod:`repro.training.checkpoint` (atomic publish:
        npz shards + manifest, tmp-dir ``os.replace``), so a crash
        mid-save never corrupts the recoverable state.  The cache is
        saved in its slot-linear MONOLITHIC view — for a paged session
        the handle's blocks are gathered first — which makes checkpoints
        portable across engine representations: a session saved on a
        paged engine restores onto a monolithic one and vice versa.

        Exactness matches the gather/scatter round-trip: global-attention
        KV and recurrent state rows restore bitwise; a local-attention
        ring that has already wrapped (``pos > window``) is clamped to
        its window view.  Inexact handles (mid-chunk stop retirement)
        keep their ``exact=False`` flag through the round-trip.
        """
        from repro.training import checkpoint as ck
        self._state_kind_check(state)
        if self.paged:
            h = state.cache
            cov_len = int(h.tables.shape[1]) * self.block_len
            cache = T.paged_gather(
                self.cfg, self._paged_dev_cache(h.tables, h.rows))
        else:
            cov_len = int(state.max_len)
            cache = state.cache
        # the per-layer cache dicts hold None for state kinds a layer does
        # not carry — flatten to the real leaves (checkpoint shards are
        # arrays only) and rebuild the structure from init_cache on restore
        tree = {"cache": jax.tree_util.tree_leaves(cache),
                "pos": np.asarray(state.pos),
                "cur": np.asarray(state.cur),
                "last": np.asarray(state.last)}
        if state.rng is not None:
            tree["rng"] = np.asarray(state.rng)
        extra = {"kind": "session", "batch": int(state.batch),
                 "max_len": int(state.max_len), "offset": int(state.offset),
                 "cov_len": cov_len, "exact": bool(state.exact),
                 "paged": bool(self.paged), "has_rng": state.rng is not None,
                 # the saved linear view was dequantized by paged_gather, so
                 # the shards are always bf16 — but a quantized session's
                 # numerics are budgeted, not bitwise, and restoring it into
                 # a differently-represented cache would silently change the
                 # conversation's precision; record the representation so
                 # restore can refuse a mismatch (QuantMismatchError)
                 "cache_quant": self.cache_quant}
        return ck.save(ckpt_dir, step, tree, extra=extra, keep=keep)

    def restore_session(self, ckpt_dir: str,
                        step: int | None = None) -> SessionState:
        """Rebuild a checkpointed session on THIS engine (possibly a fresh
        process: the one that crashed).  Paged engines scatter the saved
        linear view into freshly allocated pool blocks/rows and register
        the handle; monolithic engines adopt the arrays directly.  The
        resumed chat continues bitwise where the round-trip is exact
        (see ``checkpoint_session``)."""
        import json
        import os

        from repro.training import checkpoint as ck
        if step is None:
            step = ck.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no session checkpoint in "
                                        f"{ckpt_dir!r}")
        with open(os.path.join(ckpt_dir, f"step_{step}",
                               "manifest.json")) as f:
            extra = json.load(f)["extra"]
        if extra.get("kind") != "session":
            raise ValueError(f"checkpoint at {ckpt_dir!r} step {step} is "
                             "not a session checkpoint")
        saved_q = extra.get("cache_quant")   # absent in old checkpoints
        if saved_q != self.cache_quant:
            from repro.serving.cache_manager import QuantMismatchError
            raise QuantMismatchError(
                f"session checkpoint at {ckpt_dir!r} step {step} was saved "
                f"from a cache_quant={saved_q!r} engine but this engine is "
                f"cache_quant={self.cache_quant!r}"
                + ("" if self.paged else " (monolithic)")
                + "; restoring would silently change the session's numeric "
                "precision — restore on a matching engine or re-absorb the "
                "conversation")
        B, cov_len = int(extra["batch"]), int(extra["cov_len"])
        ab_cache = jax.eval_shape(lambda: T.init_cache(self.cfg, B, cov_len))
        ab_leaves, cache_def = jax.tree_util.tree_flatten(ab_cache)
        abstract = {
            "cache": ab_leaves,
            "pos": np.zeros((B,), np.int32),
            "cur": np.zeros((B,), np.int32),
            "last": np.zeros((B, self.cfg.vocab_size), np.float32)}
        if extra.get("has_rng"):
            abstract["rng"] = np.zeros((2,), np.uint32)
        tree, _ = ck.restore(ckpt_dir, step, abstract)
        tree["cache"] = jax.tree_util.tree_unflatten(cache_def,
                                                     tree["cache"])
        pos = jnp.asarray(np.asarray(tree["pos"], np.int32))
        cur = jnp.asarray(np.asarray(tree["cur"], np.int32))
        last = jnp.asarray(np.asarray(tree["last"], np.float32))
        rng = tree.get("rng")
        if rng is not None:
            rng = jnp.asarray(np.asarray(rng, np.uint32))
        if self.paged:
            handle = self.pool.alloc(B, cov_len // self.block_len)
            dev = self._paged_dev_cache(handle.tables, handle.rows)
            layers = T.paged_scatter_back(
                self.cfg, dev, tree["cache"],
                jnp.zeros((B,), jnp.int32),
                jnp.full((B,), cov_len, jnp.int32))
            self.pool.commit(layers)
            cache, max_len = handle, int(extra["max_len"])
        else:
            cache = jax.tree.map(jnp.asarray, tree["cache"])
            if self.mesh is not None:
                cache = jax.device_put(cache, self._cache_sh(cache))
            # the monolithic invariant is cache length == max_len: a
            # paged-saved session arrives trimmed to its covered length
            max_len = int(extra["max_len"]) if not extra.get("paged") \
                else cov_len
        return SessionState(cache, pos, cur, last, max_len,
                            int(extra["offset"]), rng=rng,
                            exact=bool(extra["exact"]))

    def fanout(self, state: SessionState, n: int) -> SessionState:
        """Fan a batch-1 session out to ``n`` rows sharing its prefix.

        Paged: a refcounted block-table copy — full prefix blocks are
        shared read-only, the partially filled tail block is copy-on-write
        per row, state rows are copied; NO prefill or cache copy happens,
        so N sessions over one absorbed system prompt cost exactly one
        prefill.  Monolithic: falls back to duplicating the cache rows
        (``state_select`` with a repeated index) — correct, but O(n * len).
        """
        if state.batch != 1:
            raise ValueError(f"fanout needs a batch-1 state, got "
                             f"{state.batch}")
        return self.state_select(state, np.zeros((n,), np.int32))

    # ------------------------------------------------------------------
    def generate(self, prompts: np.ndarray | None, max_new: int, *,
                 greedy: bool = True, seed: int = 0,
                 state: SessionState | None = None,
                 return_state: bool = False) -> dict:
        """prompts (B, S) int32, LEFT-padded with PAD=0 (HF batched-decode
        convention, so the last absorbed position is always the prompt end).

        Jitted prefill + one scanned decode, fused into a single device
        call (SPMD-partitioned when the engine has a mesh).  Returns
        ``{"tokens": (B, max_new) int32, "u": (B,) Eq. 4 difficulty,
        "logits": (B, max_new, V) f32, "prompt_lengths": (B,),
        "h_mean"/"v_mean": (B,) raw Eq. 2-3 means}`` — the probe's
        generation *is* the local answer (paper Sec. IV-A), and the Eq. 2-3
        entropy/variance terms are computed on the scanned logits at zero
        extra forward passes.

        Session API (docs/RUNTIME.md, "Continuation prefill & session
        caches"):

        * ``return_state=True`` adds ``"state"``: a :class:`SessionState`
          warm-cache handle covering the prompt plus every emitted token.
        * ``state=<handle>`` continues that session: ``prompts`` is only
          the NEW span (turn t+1's user tokens), absorbed into the live
          cache by one continuation prefill — the cached context is never
          re-prefilled.  Greedy tokens are identical to cold-prefilling the
          concatenation.
        * ``state=<handle>`` with ``prompts=None`` is a pure decode
          extension: resume from the session's pending token and emit
          ``max_new`` more — bitwise the tokens a single longer generation
          would have produced next (zero prefill dispatches of any kind).

        MoE configs take this fused path too: prefill routes each position
        as its own dispatch group with masked (capacity-excluded) bucket
        padding, and decode uses the constant-shape exact top-k dispatch —
        the same routing decisions the stepwise loop makes, so greedy
        tokens match ``generate_stepwise`` (docs/RUNTIME.md, MoE routing).
        """
        rng = jax.random.PRNGKey(seed)
        if prompts is not None:
            prompts = np.asarray(prompts, np.int32)
        if state is not None and (prompts is None or prompts.shape[1] == 0):
            self._check_state(state, extension=True)
            return self._extend(max_new, state, greedy, rng, return_state)
        if state is not None:
            self._check_state(state, extension=False)
        B, S = prompts.shape
        handle = None
        if state is None:
            pb, s_orig = self._bucket(prompts)
            max_len = self._cache_len(pb.shape[1], max_new)
            self.counters["prefill"] += 1
            if self.paged:
                handle = self.pool.alloc(B, max_len // self.block_len)
                out = _generate_fused_paged(
                    self.params, self.cfg, jnp.asarray(pb),
                    jnp.int32(s_orig),
                    self._paged_dev_cache(handle.tables, handle.rows), rng,
                    self.ucfg, int(max_new), bool(greedy),
                    impl=self.attn_decode_impl,
                    mesh=self.mesh, rules=self.rules)
            elif self.mesh is not None:
                fn = self._fused_sharded(B, pb.shape[1], max_len,
                                         int(max_new), bool(greedy))
                out = fn(self.params, jnp.asarray(pb), jnp.int32(s_orig),
                         rng)
            else:
                out = _generate_fused(
                    self.params, self.cfg, jnp.asarray(pb),
                    jnp.int32(s_orig), rng, self.ucfg, int(max_new),
                    max_len, bool(greedy))
            offset = s_orig + max_new
        else:
            if state.batch != B:
                raise ValueError(f"state batch {state.batch} != prompt "
                                 f"batch {B}")
            pb, s_orig = self._bucket_right(prompts)
            need = state.offset + pb.shape[1] + max_new
            if self.paged:
                handle = state.cache
                cache, max_len = self._paged_grown(state, need)
            else:
                cache, max_len = self._grown_cache(state, need)
            self.counters["prefill_continue"] += 1
            if self.paged:
                out = _generate_continue_paged(
                    self.params, self.cfg, jnp.asarray(pb),
                    jnp.int32(s_orig), state.pos, cache, rng, self.ucfg,
                    int(max_new), bool(greedy),
                    impl=self.attn_decode_impl,
                    mesh=self.mesh, rules=self.rules)
            elif self.mesh is not None:
                fn = self._cont_sharded(B, pb.shape[1], max_len,
                                        int(max_new), bool(greedy))
                out = fn(self.params, jnp.asarray(pb), jnp.int32(s_orig),
                         state.pos, cache, rng)
            else:
                out = _generate_continue(
                    self.params, self.cfg, jnp.asarray(pb),
                    jnp.int32(s_orig), state.pos, cache, rng, self.ucfg,
                    int(max_new), bool(greedy))
            offset = state.offset + s_orig + max_new
        toks, lgs, u, h, v, carry = out
        res = {"tokens": np.asarray(toks),
               "u": np.asarray(u),
               "logits": lgs,
               "h_mean": np.asarray(h), "v_mean": np.asarray(v),
               "prompt_lengths": (prompts != PAD).sum(axis=1)}
        if self.paged:
            cur, last, cache, pos, crng = carry
            self.pool.commit(cache["layers"])
            if return_state:
                self.pool.trim(handle, -(-offset // self.block_len))
                res["state"] = SessionState(handle, pos, cur, last, max_len,
                                            offset, rng=crng)
            elif state is None:
                self.pool.release(handle)   # one-shot: blocks back now
            else:
                # continued session not handed back: keep only the covered
                # blocks until the caller reuses or releases the handle
                self.pool.trim(handle, -(-offset // self.block_len))
        elif return_state:
            cur, last, cache, pos, crng = carry
            res["state"] = SessionState(cache, pos, cur, last, max_len,
                                        offset, rng=crng)
        return res

    def _check_state(self, state: SessionState, *, extension: bool):
        """Refuse reuse an inexact handle can't support: one captured after
        a mid-chunk stop retirement has a corrupted pending token and (for
        recurrent mixers) a corrupted carried state — only continuation
        prefill on an attention-only model survives that (the prefill
        replaces cur/last and stale KV entries are masked/overwritten)."""
        self._state_kind_check(state)
        if state.exact:
            return
        if extension or self._recurrent:
            raise ValueError(
                "inexact session state (captured after a mid-chunk stop "
                "retirement in serve()): "
                + ("pure decode extension needs the pending token"
                   if extension else
                   "recurrent-mixer state absorbed post-stop steps")
                + "; re-serve with max_new-aligned retirement or an "
                  "attention-only model")

    def _state_kind_check(self, state: SessionState):
        """A paged engine only accepts PagedHandle-backed states (and vice
        versa — the cache representations are not interchangeable), and a
        paged handle must still be registered with the pool (released /
        TTL-evicted handles raise EvictedSessionError)."""
        got = isinstance(state.cache, PagedHandle)
        if got != self.paged:
            raise ValueError(
                f"session state is {'paged' if got else 'monolithic'} but "
                f"this engine is {'paged' if self.paged else 'monolithic'}")
        if self.paged:
            self.pool.check(state.cache)

    def absorb(self, prompts: np.ndarray, *,
               state: SessionState | None = None) -> SessionState:
        """Prefill-only: absorb a context into a (fresh or live) cache and
        return the session handle — no decode steps run.

        The returned state's pending token is the prefill argmax, so
        ``generate(None, n, state=eng.absorb(p))`` emits exactly the greedy
        tokens ``generate(p, n)`` would.  Use it to cache a shared context
        (system prompt, conversation so far) once and fan generations out
        of it; continuation over an absorb-only state is **bitwise**
        identical to cold-prefilling the concatenation (no decode-written
        K/V in between — see docs/RUNTIME.md on the numerics).
        With ``state`` given, the new span is absorbed on top (prefill-only
        multi-turn ingestion).
        """
        prompts = np.asarray(prompts, np.int32)
        B, S = prompts.shape
        handle = None
        if state is None:
            pb, s_orig = self._bucket(prompts)
            max_len = self._cache_len(pb.shape[1], 0)
            self.counters["prefill"] += 1
            if self.paged:
                handle = self.pool.alloc(B, max_len // self.block_len)
                cache = self._paged_dev_cache(handle.tables, handle.rows)
                cur, last, cache = _prefill_into_paged(
                    self.params, self.cfg, jnp.asarray(pb),
                    jnp.int32(s_orig), cache, mesh=self.mesh,
                    rules=self.rules)
            else:
                cur, last, cache = _prefill_absorb(
                    self.params, self.cfg, jnp.asarray(pb), jnp.int32(s_orig),
                    max_len, mesh=self.mesh, rules=self.rules)
            pos, offset = jnp.full((B,), s_orig, jnp.int32), s_orig
        else:
            if state.batch != B:
                raise ValueError(f"state batch {state.batch} != prompt "
                                 f"batch {B}")
            self._state_kind_check(state)
            pb, s_orig = self._bucket_right(prompts)
            need = state.offset + pb.shape[1]
            if self.paged:
                handle = state.cache
                cache, max_len = self._paged_grown(state, need)
            else:
                cache, max_len = self._grown_cache(state, need)
            self.counters["prefill_continue"] += 1
            fn = _prefill_continue_paged if self.paged else _prefill_continue
            cur, last, cache = fn(
                self.params, self.cfg, jnp.asarray(pb), jnp.int32(s_orig),
                state.pos, cache, mesh=self.mesh, rules=self.rules)
            pos, offset = state.pos + s_orig, state.offset + s_orig
        if self.paged:
            self.pool.commit(cache["layers"])
            self.pool.trim(handle, -(-offset // self.block_len))
            cache = handle
        return SessionState(cache, pos, cur, last, max_len, offset)

    def _extend(self, max_new: int, state: SessionState, greedy: bool,
                rng, return_state: bool) -> dict:
        """Decode-only continuation: emit ``max_new`` more tokens from the
        session's pending token — exactly the tokens a longer original
        generation would have produced next (bitwise; the decode scan is
        sequential, and the carried rng resumes the sampling stream, so
        this holds for greedy AND sampled decode — states without a
        carried rng, e.g. serve()-extracted ones, restart the stream from
        ``seed`` and are bitwise for greedy only)."""
        if self.paged:
            cache, max_len = self._paged_grown(state, state.offset + max_new)
        else:
            cache, max_len = self._grown_cache(state, state.offset + max_new)
        self.counters["decode_only"] += 1
        if state.rng is not None:
            rng = state.rng
        B = state.batch
        if self.paged:
            toks, lgs, h_per, v_per, carry = _decode_scan_paged(
                self.params, self.cfg, state.cur, state.last, cache,
                state.pos, rng, self.ucfg, int(max_new), bool(greedy),
                impl=self.attn_decode_impl,
                mesh=self.mesh, rules=self.rules)
        elif self.mesh is not None:
            toks, h_per, v_per, carry = self._decode_sharded(
                B, max_len, int(max_new), bool(greedy))(
                    self.params, state.cur, state.last, cache, state.pos,
                    rng)
            lgs = None
        else:
            toks, lgs, h_per, v_per, carry = _decode_scan(
                self.params, self.cfg, state.cur, state.last, cache,
                state.pos, rng, self.ucfg, int(max_new), bool(greedy))
        h, v = np.asarray(h_per).mean(-1), np.asarray(v_per).mean(-1)
        res = {"tokens": np.asarray(toks),
               "u": np.asarray(U.combine_terms(h, v, self.ucfg)),
               "logits": lgs, "h_mean": h, "v_mean": v,
               "prompt_lengths": np.zeros((B,), np.int64)}
        offset = state.offset + max_new
        if self.paged:
            cur, last, cache, pos, crng = carry
            self.pool.commit(cache["layers"])
            self.pool.trim(state.cache, -(-offset // self.block_len))
            if return_state:
                res["state"] = SessionState(state.cache, pos, cur, last,
                                            max_len, offset, rng=crng)
        elif return_state:
            cur, last, cache, pos, crng = carry
            res["state"] = SessionState(cache, pos, cur, last, max_len,
                                        offset, rng=crng)
        return res

    def state_select(self, state: SessionState, idx) -> SessionState:
        """Slice (or fan out — repeated indices are fine) a batched session
        handle to rows ``idx``.  Used by the gateway to hand the swarm
        round the probe's state for just the SWARM-routed queries.

        Monolithic: materialises the selected cache rows (O(rows * len)).
        Paged: a refcounted block-table copy + a state-row device copy —
        the probe -> swarm handoff becomes O(table), and shared blocks are
        protected by COW on the next write."""
        idx_np = np.asarray(idx, np.int32)
        idx = jnp.asarray(idx_np)
        if self.paged:
            self._state_kind_check(state)
            handle = self.pool.select(state.cache, idx_np)
            cache = handle
        else:
            axes = self._slot_batch_axes(state.max_len)
            cache = jax.tree.map(lambda s, ax: jnp.take(s, idx, axis=ax),
                                 state.cache, axes)
            if self.mesh is not None:
                cache = jax.device_put(cache, self._cache_sh(cache))
        return SessionState(cache, jnp.take(state.pos, idx),
                            jnp.take(state.cur, idx),
                            jnp.take(state.last, idx, axis=0),
                            state.max_len, state.offset,
                            rng=state.rng, exact=state.exact)

    # ------------------------------------------------------------------
    def generate_stepwise(self, prompts: np.ndarray, max_new: int, *,
                          greedy: bool = True, seed: int = 0) -> dict:
        """Legacy one-token-at-a-time absorption path (S + max_new jitted
        dispatches).  Kept as the parity oracle for ``generate`` and as the
        baseline for the prefill_vs_stepwise benchmark.

        The cache length is derived from the same ``_bucket`` shape
        ``generate`` uses (only the real S columns are absorbed — inert
        bucket columns would need decode-path negative-position support),
        so ``_step`` specialises per (B, bucket) instead of re-jitting for
        every exact (B, S) the parity tests and benchmarks throw at it."""
        prompts = np.asarray(prompts, np.int32)
        B, S = prompts.shape
        pb, _ = self._bucket(prompts)
        cache = T.init_cache(self.cfg, B, self._cache_len(pb.shape[1],
                                                          max_new))
        if self.mesh is not None:
            cache = jax.device_put(cache, self._cache_sh(cache))
        else:
            cache = jax.tree.map(jnp.asarray, cache)
        rng = jax.random.PRNGKey(seed)

        lengths = (prompts != PAD).sum(axis=1)
        nxt = None
        for t in range(S):
            tok = jnp.asarray(prompts[:, t:t + 1])
            # absorption always runs greedy, so _step's sampling branch is
            # never traced and needs no key; threading the live `rng`
            # through S calls would alias the decode stream's key
            nxt, last_logits, cache = _step(
                self.params, self.cfg, tok, cache,
                jnp.full((B,), t, jnp.int32), None, True,
                mesh=self.mesh, rules=self.rules)

        out_tokens = []
        out_logits = []
        cur = nxt
        for n in range(max_new):
            out_tokens.append(cur)
            out_logits.append(last_logits)
            rng, sub = jax.random.split(rng)
            cur, last_logits, cache = _step(
                self.params, self.cfg, cur[:, None], cache,
                jnp.full((B,), S + n, jnp.int32), sub, greedy,
                mesh=self.mesh, rules=self.rules)

        tokens = jnp.stack(out_tokens, axis=1)              # (B, N)
        logits = jnp.stack(out_logits, axis=1)              # (B, N, V)
        u = U.difficulty(logits, tokens, self.ucfg)         # (B,)
        return {"tokens": np.asarray(tokens),
                "u": np.asarray(u),
                "logits": logits,
                "prompt_lengths": np.asarray(lengths)}

    # ------------------------------------------------------------------
    # Streaming serve: continuous batching over fixed decode slots
    # ------------------------------------------------------------------

    def _slot_batch_axes(self, max_len: int):
        """Per-leaf batch axis of the cache pytree (stacked scan stages
        carry their repeat dim in front of batch)."""
        a1 = jax.eval_shape(lambda: T.init_cache(self.cfg, 1, max_len))
        a2 = jax.eval_shape(lambda: T.init_cache(self.cfg, 2, max_len))
        return jax.tree.map(
            lambda x, y: next(i for i, (p, q) in enumerate(zip(x.shape, y.shape))
                              if p != q), a1, a2)

    def _slot_insert(self):
        """Jitted cache splice, built once per engine (jit re-specialises on
        shapes by itself, so one closure covers every max_len/n_slots)."""
        fn = getattr(self, "_slot_insert_fn", None)
        if fn is None:
            axes = self._slot_batch_axes(self.max_len)
            cfg, mesh, rules = self.cfg, self.mesh, self.rules

            @jax.jit
            def fn(slots, one, i):
                out = jax.tree.map(
                    lambda s, o, ax: jax.lax.dynamic_update_index_in_dim(
                        s, jax.lax.index_in_dim(o, 0, ax, keepdims=False),
                        i, ax),
                    slots, one, axes)
                # keep the slot cache pinned to its logical-axis sharding so
                # the splice doesn't force a re-layout before the next chunk
                return T.constrain_cache(out, cfg, mesh, rules)
            self._slot_insert_fn = fn
        return fn

    def _slot_extract(self):
        """Jitted inverse of ``_slot_insert``: slice slot ``i`` out of the
        slot cache as a batch-1 cache (a retiring request's session state)."""
        fn = getattr(self, "_slot_extract_fn", None)
        if fn is None:
            axes = self._slot_batch_axes(self.max_len)

            @jax.jit
            def fn(slots, i):
                return jax.tree.map(
                    lambda s, ax: jax.lax.dynamic_slice_in_dim(s, i, 1, ax),
                    slots, axes)
            self._slot_extract_fn = fn
        return fn

    def serve(self, requests: Sequence[Request] | None = None, *,
              batcher: ContinuousBatcher | None = None, n_slots: int = 4,
              decode_chunk: int = 8, stop_token: int | None = None,
              greedy: bool = True, seed: int = 0,
              session_ttl_s: float | None = None,
              faults: FaultPlan | None = None,
              overload: str = "raise",
              step_time_ms: float | None = None) -> list[dict]:
        """Streaming entry point: requests flow through a ContinuousBatcher.

        Loop: admit queued requests into free slots (each admission is one
        jitted prefill that is spliced into the slot cache) -> one scanned
        decode chunk over ALL slots -> record tokens / retire finished slots
        (stop token or max_new) -> repeat until idle.  Requests are admitted
        mid-flight as slots free up, ordered earliest-deadline-first then by
        priority (``Request.deadline_ms`` / ``Request.priority``; FIFO among
        equals).

        Returns one dict per finished request: {"rid", "tokens", "u"},
        in completion order.  With ``greedy=True`` (default) tokens are
        bitwise-identical to ``generate`` on the same prompt.  MoE configs
        stream like any other: admission prefills route per position and
        the decode chunk routes exactly per token, so neither other
        requests in flight nor garbage in empty slots can perturb a
        request's expert routing.

        Session caches (docs/RUNTIME.md): a request with ``state`` set is
        admitted by ONE continuation prefill of its (new-span) prompt over
        the warm cache — the conversation so far is never re-absorbed.  A
        request with ``return_state=True`` gets ``"state"`` in its result
        dict, sliced out of the slot cache at retirement; the decode chunk
        is clamped to such a request's remaining budget so its slot state
        is captured exactly at its last step (a stop-token retirement
        mid-chunk still yields an exact KV cache — stale higher-position
        entries are masked and later overwritten — but the *recurrent*
        state of RG-LRU/SSD mixers would have absorbed the chunk's
        post-stop garbage steps; chunk-aligned retirement avoids that).

        Paged engines (docs/RUNTIME.md "Paged caches & prefix sharing"):
        slots reference the block pool through per-slot tables — admission
        asks the pool for blocks (requests that don't fit wait in the
        queue until retirements free blocks), a cold admission prefills
        straight into its blocks, a warm admission is a refcounted table
        copy off the session handle (shared prefix blocks are NOT copied;
        N requests carrying the SAME absorbed handle fan its prefix out
        with zero extra prefills), retirement returns blocks to the pool,
        and ``return_state`` hand-back is a table adoption trimmed to the
        covered length — no cache extraction copy.  ``session_ttl_s``
        evicts registered sessions idle past the TTL whenever the pool
        runs out of blocks (their handles raise on reuse).

        Failure semantics (docs/RUNTIME.md "Failure semantics"):

        * pool famine is *backpressure*, not a crash — admissions defer
          while anything is decoding; a hard wedge (nothing decoding,
          nothing admissible even after the TTL sweep) raises
          ``PoolExhaustedError`` with ``overload="raise"`` (default) or,
          with ``overload="shed"``, retires the least-urgent queued
          request marked ``shed=True`` and keeps going (the gateway's
          cloud path is the recourse for shed work);
        * a warm request whose handle was evicted is transparently
          re-admitted COLD (``Request.cold_prompt`` when provided, else
          its ``prompt``), counted in ``counters["reprefill_cold"]``;
          a pure decode-resume with no recoverable prompt retires shed;
        * ``faults`` injects execution failures (serving/faults.py):
          "pool"/famine defers one admission round, "session"/evict
          force-releases the next warm admission's handle, "slot"/fail
          kills the lowest active slot after the current chunk — its
          request is requeued and re-admitted off its still-valid warm
          handle (or cold);
        * ``step_time_ms`` arms the deadline clock: each decode step
          advances a simulated clock by that many ms (plus any injected
          "decode"/straggle delay) and requests whose ``deadline_ms``
          has passed retire ``shed=True`` — queued ones before taking a
          slot, active ones mid-decode with what they have.  ``None``
          (default) keeps deadlines as pure admission ordering.
        """
        if (requests is None) == (batcher is None):
            raise ValueError("pass exactly one of requests / batcher")
        if batcher is None:
            batcher = ContinuousBatcher(n_slots)
            for r in requests:
                batcher.submit(r)
        if any(s is not None for s in batcher.slots):
            # a slot occupied before this call has no prefilled cache here —
            # decoding it would silently emit garbage
            raise ValueError("serve() requires an un-admitted batcher: "
                             "submit requests to the queue only")
        n_slots = batcher.n_slots

        pending = list(batcher.queue)
        if not pending:
            return []
        gran = max(self.cfg.attn_q_block, self.cfg.attn_kv_block)

        def _need(r: Request) -> int:
            # warm requests need room for the session so far + the new span
            off = r.state.offset if r.state is not None else 0
            sb = bucket_len(len(r.prompt), gran) if r.prompt else 0
            n = self._cache_len(off + sb, r.max_new)
            if r.cold_prompt:
                # the slot must also fit the cold-re-prefill fallback
                # (full conversation) should the warm handle be lost
                n = max(n, self._cache_len(
                    bucket_len(len(r.cold_prompt), gran), r.max_new))
            return max(n, r.state.max_len) if r.state is not None else n

        max_len = max(_need(r) for r in pending)

        paged = self.paged
        V = self.cfg.vocab_size
        cur = jnp.zeros((n_slots,), jnp.int32)
        last = jnp.zeros((n_slots, V), jnp.float32)
        pos = jnp.zeros((n_slots,), jnp.int32)
        if paged:
            nb = max_len // self.block_len
            # sentinel table/row ids: empty slots decode harmlessly — their
            # pool writes are dropped (out-of-range scatter) and their
            # reads clip, so they own no storage and can corrupt none
            slot_tables = np.full((n_slots, nb), self.pool.n_blocks,
                                  np.int32)
            slot_rows = np.full((n_slots,), self.pool.n_rows, np.int32)
            slot_run: list = [None] * n_slots      # owned (blocks, row)
            cache = None
        else:
            cache = T.init_cache(self.cfg, n_slots, max_len)
            cache = (jax.device_put(cache, self._cache_sh(cache))
                     if self.mesh is not None
                     else jax.tree.map(jnp.asarray, cache))
        if self.mesh is not None:
            # place the slot state by the activation rules up front: batch
            # on 'data', logits vocab on 'model', cache per cache_axes
            cur = jax.device_put(cur, self._act_sh(cur.shape, ("act_batch",)))
            last = jax.device_put(last, self._act_sh(
                last.shape, ("act_batch", "act_vocab")))
            pos = jax.device_put(pos, self._act_sh(pos.shape, ("act_batch",)))
        rng = jax.random.PRNGKey(seed)
        insert = self._slot_insert() if not paged else None

        acc: dict[int, list] = {}       # rid -> [sum_h, sum_v, n]
        states: dict[int, SessionState] = {}    # rid -> extracted state
        pos0: dict[int, int] = {}       # slot -> position at admission
        results: list[dict] = []
        extract = self._slot_extract() if not paged else None

        def drain():
            for req in batcher.drain_finished():
                h, v, n = acc.pop(req.rid, (0.0, 0.0, 0))
                d = max(n, 1)
                out = {"rid": req.rid,
                       "tokens": np.asarray(req.generated, np.int32),
                       "u": float(U.combine_terms(h / d, v / d, self.ucfg))}
                if req.shed:
                    out["shed"] = True
                if req.rid in states:
                    out["state"] = states.pop(req.rid)
                results.append(out)

        promised = [0]          # slots admitted this round, not yet funded

        def fits(r: Request) -> bool:
            # admission asks the pool: a cold request needs a full run +
            # a state row, a warm one at most a COW tail + the unshared
            # remainder (bounded by the same) — be conservative.  admit()
            # may fill several slots before the engine allocates, so count
            # the slots already promised this round; the batcher admits a
            # request exactly when its fits() returned True, so the
            # increment below tracks admissions one-for-one.
            ok = self.pool.can_alloc((promised[0] + 1) * nb,
                                     promised[0] + 1)
            if ok:
                promised[0] += 1
            return ok

        now_ms = 0.0

        while not batcher.idle:
            if (faults is not None and batcher.queue
                    and faults.consume("pool") is not None):
                # injected famine: this admission round sees zero free
                # blocks.  Backpressure, not a crash — queued requests
                # simply wait the round out while anything active keeps
                # decoding; with nothing active we skip the (empty-slot)
                # dispatch entirely.
                self.counters["famine_deferred"] += len(batcher.queue)
                admitted = []
                if not batcher.active():
                    continue
            else:
                promised[0] = 0
                admitted = batcher.admit(fits=fits if paged else None)
                if paged and not admitted and not batcher.active() \
                        and batcher.queue:
                    # pool famine with nothing decoding: TTL-evict idle
                    # sessions to recover blocks — except the handles queued
                    # warm requests still reference — then retry once
                    if session_ttl_s is not None:
                        keep = {r.state.cache.sid for r in batcher.queue
                                if r.state is not None
                                and isinstance(r.state.cache, PagedHandle)}
                        self.pool.evict_idle(session_ttl_s, exclude=keep)
                    promised[0] = 0
                    admitted = batcher.admit(fits=fits)
                    if not admitted:
                        if overload == "shed" \
                                and batcher.shed_one() is not None:
                            # hard wedge: retire the least-urgent queued
                            # request with shed=True and keep serving —
                            # the caller reroutes shed work (cloud path)
                            self.counters["shed"] += 1
                            continue
                        raise PoolExhaustedError(
                            f"cache pool exhausted: "
                            f"{self.pool.blocks_in_use}/"
                            f"{self.pool.n_blocks} blocks "
                            f"({self.pool._famine_detail()}) held by "
                            f"{self.pool.live_sessions} sessions and no "
                            "slot can admit — grow pool_blocks, release "
                            "sessions, or pass session_ttl_s")
            for i in admitted:
                req = batcher.slots[i]
                st = req.state
                if (st is not None and faults is not None
                        and isinstance(st.cache, PagedHandle)
                        and faults.consume("session") is not None):
                    # injected forced eviction: the handle is genuinely
                    # released so the recovery below is the real path
                    self.release(st)
                if st is not None:
                    try:
                        self._check_state(st, extension=not req.prompt)
                    except EvictedSessionError:
                        # the session handle is gone (TTL sweep, forced
                        # eviction): transparently re-admit COLD from the
                        # full-conversation prompt instead of failing
                        req.state = st = None
                        if req.cold_prompt is not None:
                            req.prompt = list(req.cold_prompt)
                        if not req.prompt:
                            # decode-resume with nothing to re-prefill
                            req.done = True
                            req.shed = True
                            batcher.finished.append(req)
                            batcher.slots[i] = None
                            self.counters["shed"] += 1
                            continue
                        self.counters["reprefill_cold"] += 1
                if paged:
                    if st is not None:
                        # warm admission: the slot's table row shares the
                        # session's prefix blocks by reference (COW tail) —
                        # the handle itself is untouched, so many requests
                        # can fan out of one absorbed prefix
                        run, row = self.pool.admit_row(
                            st.cache, nb, int(np.asarray(st.pos)[0]))
                    else:
                        blocks, row = self.pool.alloc_run(nb)
                        run = blocks
                    slot_tables[i, :] = run
                    slot_rows[i] = row
                    slot_run[i] = (run, row)
                    c1g = self._paged_dev_cache(slot_tables[i:i + 1],
                                                slot_rows[i:i + 1])
                elif st is not None:
                    c1g, _ = self._grown_cache(st, max_len)
                if st is not None:
                    # warm admission: continuation-prefill only the new
                    # span — the conversation so far is NOT re-absorbed
                    if req.prompt:
                        p = np.asarray(req.prompt, np.int32)[None]
                        pb, s_orig = self._bucket_right(p)
                        self.counters["prefill_continue"] += 1
                        fn = (_prefill_continue_paged if paged
                              else _prefill_continue)
                        c1, l1, k1 = fn(
                            self.params, self.cfg, jnp.asarray(pb),
                            jnp.int32(s_orig), st.pos, c1g,
                            mesh=self.mesh, rules=self.rules)
                        p0 = st.offset + s_orig
                    else:                      # pure decode resume
                        self.counters["decode_only"] += 1
                        c1, l1, k1 = st.cur, st.last, c1g
                        p0 = st.offset
                else:
                    p = np.asarray(req.prompt, np.int32)[None]
                    pb, s_orig = self._bucket(p)
                    self.counters["prefill"] += 1
                    if paged:
                        c1, l1, k1 = _prefill_into_paged(
                            self.params, self.cfg, jnp.asarray(pb),
                            jnp.int32(s_orig),
                            self._paged_dev_cache(slot_tables[i:i + 1],
                                                  slot_rows[i:i + 1]),
                            mesh=self.mesh, rules=self.rules)
                    else:
                        c1, l1, k1 = _prefill_absorb(
                            self.params, self.cfg, jnp.asarray(pb),
                            jnp.int32(s_orig), max_len,
                            mesh=self.mesh, rules=self.rules)
                    p0 = s_orig
                if paged:
                    # admission prefilled straight into the slot's pool
                    # blocks — commit the pool, nothing to splice
                    if T.is_paged(k1):
                        self.pool.commit(k1["layers"])
                else:
                    cache = insert(cache, k1, i)
                cur = cur.at[i].set(c1[0])
                last = last.at[i].set(l1[0])
                pos = pos.at[i].set(p0)
                pos0[i] = p0

            # clamp the chunk so a return_state request's last step lands on
            # a chunk boundary — its slot state is then captured exactly.
            # Each distinct clamped size jits its own decode scan, but only
            # once per engine and only for sizes < decode_chunk that
            # return_state requests actually hit near retirement (bounded
            # by decode_chunk, not by the request mix).
            chunk = min([int(decode_chunk)] +
                        [r.max_new - len(r.generated)
                         for _, r in batcher.active() if r.return_state])
            if paged:
                cache = self._paged_dev_cache(slot_tables, slot_rows)
                toks, _, h_per, v_per, carry = _decode_scan_paged(
                    self.params, self.cfg, cur, last, cache, pos, rng,
                    self.ucfg, chunk, bool(greedy), with_logits=False,
                    impl=self.attn_decode_impl,
                    mesh=self.mesh, rules=self.rules)
            elif self.mesh is not None:
                toks, h_per, v_per, carry = self._decode_sharded(
                    n_slots, max_len, chunk, bool(greedy))(
                        self.params, cur, last, cache, pos, rng)
            else:
                toks, _, h_per, v_per, carry = _decode_scan(
                    self.params, self.cfg, cur, last, cache, pos, rng,
                    self.ucfg, chunk, bool(greedy), with_logits=False)
            cur, last, cache, pos, rng = carry
            if paged:
                self.pool.commit(cache["layers"])
            toks_np = np.asarray(toks)
            h_np, v_np = np.asarray(h_per), np.asarray(v_per)

            slot_of = {r.rid: i for i, r in batcher.active()}
            retired_at: dict[int, int] = {}
            for t in range(chunk):
                active = batcher.active()
                if not active:
                    break
                for i, req in active:
                    a = acc.setdefault(req.rid, [0.0, 0.0, 0])
                    a[0] += float(h_np[i, t])
                    a[1] += float(v_np[i, t])
                    a[2] += 1
                batcher.record_tokens(toks_np[:, t], stop_token)
                for i, req in active:
                    if req.done:
                        retired_at.setdefault(req.rid, t)
            for req in batcher.finished:        # retired this chunk
                i = slot_of.get(req.rid)
                if i is None:
                    continue
                want_state = req.return_state and req.rid not in states
                # a request whose last step is the chunk's last step (the
                # clamp guarantees this for max_new retirement) is captured
                # exactly; a stop-token retirement mid-chunk left the slot
                # decoding garbage -> the handle is marked inexact and only
                # supports continuation prefill on attention-only models
                end = pos0[i] + len(req.generated)
                exact = retired_at.get(req.rid) == chunk - 1
                if paged and slot_run[i] is not None:
                    blocks, row = slot_run[i]
                    if want_state:
                        # hand-back = table adoption, trimmed to the
                        # covered blocks — no cache extraction copy
                        handle = self.pool.adopt(
                            blocks, row, -(-end // self.block_len))
                        states[req.rid] = SessionState(
                            handle, jnp.full((1,), end, jnp.int32),
                            cur[i:i + 1], last[i:i + 1], max_len, end,
                            exact=exact)
                    else:
                        self.pool.free_blocks(blocks)
                        self.pool.free_rows(np.array([row]))
                    # repoint the slot at the sentinels: its garbage decode
                    # keeps running but writes are dropped from here on
                    slot_tables[i, :] = self.pool.n_blocks
                    slot_rows[i] = self.pool.n_rows
                    slot_run[i] = None
                elif want_state:
                    states[req.rid] = SessionState(
                        extract(cache, i), jnp.full((1,), end, jnp.int32),
                        cur[i:i + 1], last[i:i + 1], max_len, end,
                        exact=exact)

            def _free_slot(i: int):
                # drop a live slot's pool resources and repoint it at the
                # sentinels (its garbage decode keeps running, writes drop)
                if paged and slot_run[i] is not None:
                    blocks, row = slot_run[i]
                    self.pool.free_blocks(blocks)
                    self.pool.free_rows(np.array([row]))
                    slot_tables[i, :] = self.pool.n_blocks
                    slot_rows[i] = self.pool.n_rows
                    slot_run[i] = None
                pos0.pop(i, None)

            if step_time_ms is not None:
                # simulated wall clock for deadline expiry: decode steps
                # cost step_time_ms each, plus any injected straggle
                now_ms += chunk * float(step_time_ms)
                if faults is not None:
                    ev = faults.consume("decode")
                    if ev is not None:
                        now_ms += 1000.0 * float(ev.delay_s)
                for i, req in batcher.expire(now_ms):
                    self.counters["expired"] += 1
                    if i is not None:
                        _free_slot(i)

            if faults is not None and faults.consume("slot") is not None:
                # injected slot failure: the lowest active slot dies after
                # this chunk.  Its decode progress is lost; the request
                # goes back in the queue and re-admits off its warm handle
                # when that is still valid (continuation prefill), else
                # cold (the admission path handles the evicted case).
                act = batcher.active()
                if act:
                    i, req = act[0]
                    _free_slot(i)
                    acc.pop(req.rid, None)
                    batcher.requeue(i)
                    self.counters["requeued"] += 1
            drain()
        drain()
        return results

    # ------------------------------------------------------------------
    def token_count(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        return (np.asarray(prompts) != PAD).sum(axis=1) + max_new
