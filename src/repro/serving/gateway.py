"""The SWARM-LLM gateway: Algorithm 1 end-to-end over query batches.

Wires together every core component — safety gate (Eq. 5-6), probe
uncertainty (Eq. 2-4), threshold routing + hard budget (Sec. IV-F, Eq. 13),
swarm collaboration + weighted consensus (Eq. 14), cloud escalation with
the O5 degradation chain, privacy logging (Eq. 15-17) and the distillation
buffer (Sec. IV-H).  Model execution is real; link timings come from the
simulator (see serving/simulator.py docstring).

The probe SLM *is* the local SLM (paper Sec. IV-A): its generation doubles
as the local answer, so Level-0 queries cost exactly one SLM pass.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import budget as budget_lib
from repro.core import cost_model as cm
from repro.core import router as router_lib
from repro.core.distill import DistillBuffer
from repro.core.privacy import privacy_metrics
from repro.core.router import (CLOUD, CLOUD_SAFETY, LOCAL, REFUSE, SWARM,
                               RouterConfig)
from repro.core.safety import safety_score
from repro.data.workload import REFUSAL, is_correct
from repro.serving.engine import InferenceEngine
from repro.serving.faults import (CircuitBreaker, CloudUnavailableError,
                                  FaultPlan, HealthRegistry, RetryPolicy)
from repro.serving.simulator import NetworkSimulator
from repro.serving.swarm import SwarmExecutor, pad_prompts, truncate_at_stop

#: engine-side failure counters the gateway folds into GatewayLog.faults
#: (per-batch deltas summed over swarm members)
_ENGINE_FAULT_KEYS = ("famine_deferred", "shed", "requeued",
                      "reprefill_cold", "expired")


@dataclasses.dataclass
class GatewayLog:
    decision: np.ndarray        # (Q,) router codes
    u: np.ndarray               # (Q,) difficulty
    safety: np.ndarray          # (Q,) safety score s
    latency: np.ndarray         # (Q,) end-to-end seconds
    cost: np.ndarray            # (Q,) dollars
    prompt_len: np.ndarray      # (Q,) prompt length (chars proxy = tokens)
    category: list              # (Q,) easy|hard|safety
    correct: np.ndarray         # (Q,) bool (False where no gold)
    answers: np.ndarray         # (Q, N) final answer tokens
    consensus: np.ndarray       # (Q,) best cluster score (NaN if no swarm)
    # failure-domain record (docs/RUNTIME.md "Failure semantics"): retry/
    # degradation/shed counters for this batch — cloud summon attempts and
    # failures, circuit-breaker transitions, member casualties/straggle,
    # and the swarm engines' famine/shed/requeue/re-prefill deltas.
    faults: dict = dataclasses.field(default_factory=dict)
    # (Q,) bool: the query got a *served* response (a safety-policy refusal
    # counts as served; a degradation-forced refusal — cloud required but
    # unreachable after retries — does not)
    answered: np.ndarray | None = None

    def availability(self) -> float:
        """Fraction of queries that received a served answer (Table V-style
        robustness metric: accuracy tells how good the answers were,
        availability tells how many queries got one at all)."""
        return 1.0 if self.answered is None else float(self.answered.mean())

    def cloud_usage(self) -> float:
        return float(np.mean((self.decision == CLOUD)
                             | (self.decision == CLOUD_SAFETY)))

    def accuracy(self, category: str | None = None) -> float:
        sel = np.array([c != "safety" and (category is None or c == category)
                        for c in self.category])
        return float(self.correct[sel].mean()) if sel.any() else float("nan")

    def privacy(self):
        is_saf = np.array([c == "safety" for c in self.category])
        return privacy_metrics(jnp.asarray(self.decision),
                               jnp.asarray(self.prompt_len),
                               jnp.asarray(is_saf))


@dataclasses.dataclass
class Gateway:
    probe: InferenceEngine                  # local SLM / probe (Tier 1)
    swarm: SwarmExecutor                    # peers (includes probe or not)
    cloud: InferenceEngine | None           # Foundation Nexus (Tier 2)
    safety_params: Any
    safety_cfg: Any
    router_cfg: RouterConfig
    sim: NetworkSimulator
    cost_params: cm.CostParams = dataclasses.field(default_factory=cm.CostParams)
    lat_params: cm.LatencyParams = dataclasses.field(default_factory=cm.LatencyParams)
    budget_total: float = 1.0
    max_new: int = 8
    quorum: int | None = None               # beyond-paper straggler mitigation
    distill_buffer: DistillBuffer = dataclasses.field(default_factory=DistillBuffer)
    # failure-domain runtime (serving/faults.py).  ``faults=None`` (or an
    # empty plan) leaves every code path bitwise-identical to the pre-
    # fault-injection gateway: the retry loop's first attempt is the old
    # single call, backoff jitter draws only from the PLAN's rng (never
    # the simulator's), and the breaker/health registry only change
    # routing after an actual failure.
    faults: FaultPlan | None = None
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    breaker: CircuitBreaker = dataclasses.field(default_factory=CircuitBreaker)

    def __post_init__(self):
        self.budget = budget_lib.init_budget(self.budget_total)
        self._tick = 0
        self.health = HealthRegistry(len(self.swarm.members))
        if self.faults is not None and self.swarm.faults is None:
            self.swarm.faults = self.faults

    def reset_fault_state(self):
        """Rewind everything a determinism re-run needs fresh: budget,
        batch tick, breaker, health registry, the fault plan's schedule
        and rng, and the network simulator's seeded state.  Two identical
        workload runs bracketed by this produce identical winners and
        identical fault/retry/shed counters."""
        self.budget = budget_lib.init_budget(self.budget_total)
        self._tick = 0
        self.breaker.reset()
        self.health = HealthRegistry(len(self.swarm.members))
        self.sim.reset()
        if self.faults is not None:
            self.faults.reset()

    # ------------------------------------------------------------------
    def answer_batch(self, queries: list[dict], seed: int = 0) -> GatewayLog:
        """Algorithm 1 over one query batch.

        queries: list of ``{"prompt": [tokens], "gold": int|None,
        "category": "easy"|"hard"|"safety"}`` (see data/workload.py).
        Returns a :class:`GatewayLog` with per-query routing decisions,
        Eq. 2-4 difficulty, Eq. 5 safety scores, Eq. 7-12 latency/cost,
        Eq. 14 consensus scores, final answers and correctness — the
        record the Table III/IV/V metrics and Eq. 15-17 privacy terms are
        computed from.
        """
        B = len(queries)
        prompts = pad_prompts([q["prompt"] for q in queries])
        plen = (prompts != 0).sum(axis=1)
        self.sim.tick()
        self._tick += 1
        self.health.tick()
        if self.faults is not None:
            self.faults.tick()
        fc = {"cloud_attempts": 0, "cloud_retries": 0, "cloud_failures": 0,
              "cloud_exhausted": 0, "breaker_opened": 0,
              "breaker_open_skips": 0, "degraded_to_swarm": 0,
              "degraded_to_local": 0, "degraded_refused": 0,
              "member_casualties": 0, "member_straggle_s": 0.0}
        fc.update({k: 0 for k in _ENGINE_FAULT_KEYS})
        eng0 = self._member_counters()
        brk0 = self.breaker.opened_count
        answered = np.ones((B,), bool)
        wan_ok = bool(self.sim.wan_up)
        # the WAN gate is one input to the cloud-availability signal; the
        # circuit breaker (opened by exhausted summon retries, half-open
        # after cooldown_ticks) is the other.  An open breaker degrades
        # routing exactly like an outage: the O5 chain sends non-risk
        # cloud aspirants to the swarm and risk queries to REFUSE.
        breaker_ok = self.breaker.allow(self._tick)
        if wan_ok and not breaker_ok:
            fc["breaker_open_skips"] += 1
        cloud_ok = wan_ok and breaker_ok

        # --- safety gate (Eq. 5); right-aligned to match classifier training
        rp = pad_prompts([q["prompt"] for q in queries], align="right")
        s = np.asarray(safety_score(self.safety_params, self.safety_cfg,
                                    jnp.asarray(rp)))

        # --- probe = local answer + difficulty (Eq. 2-4) ---
        # return_state hands back the probe's filled cache: the swarm round
        # and any escalation deepening continue from it instead of paying
        # the probe's prefill a second time
        probe_res = self.probe.generate(prompts, self.max_new, seed=seed,
                                        return_state=True)
        u = probe_res["u"]
        probe_lat = self.sim.edge_latency(plen + self.max_new)

        # --- phase A routing (Alg. 1 l.1-12, budget Eq. 13) ---
        est_cost = np.asarray(cm.cost_cloud(
            jnp.asarray(plen, jnp.float32), float(self.max_new),
            self.cost_params))
        l_cloud_est = self.lat_params.wan_rtt_mean \
            + self.lat_params.cloud_per_token * (plen + self.max_new)
        phase_a = router_lib.route(
            jnp.asarray(u), jnp.asarray(s), cfg=self.router_cfg,
            budget=self.budget, wan_ok=cloud_ok,
            est_cloud_cost=jnp.asarray(est_cost),
            l_edge=jnp.asarray(probe_lat),
            l_cloud=jnp.asarray(l_cloud_est))
        decision = np.asarray(phase_a.decision)
        self.budget = phase_a.budget

        # --- swarm round for Level-1 queries (Alg. 1 l.13-14) ---
        # answer normalisation (truncate_at_stop) is applied uniformly:
        # local, swarm and cloud answers are clustered/graded the same way
        stop = self.swarm.stop_token
        latency = probe_lat.copy()
        cost = np.zeros((B,))
        answers = truncate_at_stop(probe_res["tokens"].copy(), stop)
        consensus = np.full((B,), np.nan)
        swarm_mask = decision == SWARM
        if swarm_mask.any():
            # the probe is usually a swarm member: reuse its generation —
            # tokens, answer-span difficulty AND the warm cache handle — so
            # the round issues zero prefill dispatches for the probe member,
            # and any escalation deepening extends decode-only from the
            # live cache instead of re-prefilling the prompt
            idx = np.where(swarm_mask)[0]
            u_ans = self.swarm.member_u(self.probe, probe_res)
            pre = {j: (probe_res["tokens"][swarm_mask], u_ans[swarm_mask],
                       (probe_res["h_mean"][swarm_mask],
                        probe_res["v_mean"][swarm_mask]))
                   for j, m in enumerate(self.swarm.members)
                   if m is self.probe}
            states = {j: self.probe.state_select(probe_res["state"], idx)
                      for j in pre}
            # membership = simulator availability AND health: a member
            # past its consecutive-failure threshold is skipped until its
            # next half-open recovery probe (faults.HealthRegistry)
            up = (np.asarray(self.sim.member_up, bool)
                  & self.health.available())
            sw = self.swarm.collaborate(prompts[swarm_mask], self.max_new,
                                        member_mask=up,
                                        seed=seed, precomputed=pre,
                                        states=states)
            consensus[swarm_mask] = sw["consensus_score"]
            cas = sw.get("casualties", [])
            strag = sw.get("straggle_s", {})
            for j in cas:
                self.health.record_failure(j)
                fc["member_casualties"] += 1
            # Eq. 9 waits only on members that actually returned — down
            # peers AND mid-round casualties must not contribute an
            # edge-latency term (the crashed member's work is refunded;
            # quorum is satisfied by the survivors)
            live = up.copy()
            live[list(cas)] = False
            n_up = int(live.sum())
            if n_up > 0:
                edge_l = self.sim.edge_latency(
                    np.tile((plen[swarm_mask] + self.max_new)[:, None],
                            (1, n_up)))
                comm_l = self.sim.peer_comm(int(swarm_mask.sum()), n_up)
                # an injected straggler's delay rides on its comm term
                live_idx = np.where(live)[0]
                for c, j in enumerate(live_idx):
                    if j in strag:
                        comm_l[:, c] = comm_l[:, c] + strag[j]
                        fc["member_straggle_s"] += float(strag[j])
                sw_lat = np.asarray(cm.latency_swarm(
                    jnp.asarray(edge_l), jnp.asarray(comm_l), self.lat_params,
                    quorum=self.quorum))
                # survivors feed the health registry's EWMA latency prior
                for c, j in enumerate(live_idx):
                    self.health.record_success(
                        j, float(edge_l[:, c].mean() + comm_l[:, c].mean()))
            else:
                sw_lat = np.full((int(swarm_mask.sum()),),
                                 self.lat_params.agg_overhead)
            latency[swarm_mask] += sw_lat
            b = cm.swarm_bytes(plen[swarm_mask].astype(float),
                               float(self.max_new * n_up),
                               self.cost_params)
            cost[swarm_mask] += np.asarray(cm.cost_swarm(
                (plen[swarm_mask] + self.max_new).astype(float) * n_up,
                b, self.cost_params))
            answers[swarm_mask] = sw["winner_tokens"]

        # --- phase B: consensus gate -> escalate (Alg. 1 l.15-23) ---
        cons_arr = np.where(np.isnan(consensus), 1.0, consensus)
        phase_b = router_lib.post_consensus(
            jnp.asarray(decision), jnp.asarray(cons_arr, np.float32),
            cfg=self.router_cfg, budget=self.budget, wan_ok=cloud_ok,
            est_cloud_cost=jnp.asarray(est_cost))
        # np.array (copy): the degraded-summon path rewrites decisions in
        # place, and np.asarray over a jax array is read-only
        decision = np.array(phase_b.decision)
        self.budget = phase_b.budget

        # --- cloud execution (Tier 2): retrying summon ---
        # bounded attempts with a per-attempt deadline and jittered
        # exponential backoff (faults.RetryPolicy).  The first attempt IS
        # the old single call — with no injected fault nothing below adds
        # latency, cost or rng draws.  Exhausted retries trip the circuit
        # breaker and degrade the batch: cloud -> swarm (queries that went
        # through a round keep their consensus winner) -> local (probe
        # answer); risk queries that *required* the cloud are refused,
        # mirroring the router's O5 outage chain.
        cloud_mask = (decision == CLOUD) | (decision == CLOUD_SAFETY)
        if cloud_mask.any() and self.cloud is not None:
            cl = None
            attempts = 0
            backoff_total = 0.0
            while True:
                attempts += 1
                fc["cloud_attempts"] += 1
                try:
                    if self.faults is None:
                        cl = self.cloud.generate(prompts[cloud_mask],
                                                 self.max_new, seed=seed)
                    else:
                        cl, _ = self.faults.call(
                            "cloud",
                            lambda: self.cloud.generate(
                                prompts[cloud_mask], self.max_new,
                                seed=seed))
                    break
                except CloudUnavailableError:
                    fc["cloud_failures"] += 1
                    if attempts >= self.retry.max_attempts:
                        break
                    fc["cloud_retries"] += 1
                    backoff_total += self.retry.backoff(
                        attempts - 1,
                        self.faults.rng if self.faults is not None else None)
            failed = attempts - (1 if cl is not None else 0)
            if failed:
                # realized retry time: every failed attempt burns its
                # deadline, plus the backoff sleeps between attempts —
                # and each failed summon still shipped the prompt
                # (Eq. 7 prompt-token cost, charged against the budget)
                extra = float(np.asarray(cm.latency_retries(
                    float(failed), self.retry.timeout_s, backoff_total)))
                latency[cloud_mask] += extra
                retry_cost = failed * np.asarray(cm.cost_cloud(
                    jnp.asarray(plen[cloud_mask], jnp.float32), 0.0,
                    self.cost_params))
                cost[cloud_mask] += retry_cost
                self.budget = self.budget._replace(
                    used=self.budget.used + float(retry_cost.sum()))
            if cl is not None:
                self.breaker.record_success()
                answers[cloud_mask] = truncate_at_stop(cl["tokens"], stop)
                latency[cloud_mask] += self.sim.cloud_latency(
                    plen[cloud_mask] + self.max_new)
                cost[cloud_mask] += est_cost[cloud_mask]
                # distillation feedback loop (Sec. IV-H)
                for qi in np.where(cloud_mask)[0]:
                    self.distill_buffer.log(queries[qi]["prompt"],
                                            answers[qi].tolist(),
                                            meta={"u": float(u[qi])})
            else:
                fc["cloud_exhausted"] += 1
                self.breaker.record_failure(self._tick)
                # refund the completion cost the batch never incurred
                self.budget = self.budget._replace(
                    used=jnp.maximum(
                        self.budget.used - float(est_cost[cloud_mask].sum()),
                        0.0))
                # graceful degradation: answers[] still holds each query's
                # best pre-cloud candidate (swarm winner for escalations,
                # probe answer otherwise) — reroute instead of failing
                had_swarm = ~np.isnan(consensus)
                was_safety = decision == CLOUD_SAFETY
                to_swarm = cloud_mask & had_swarm & ~was_safety
                to_local = cloud_mask & ~had_swarm & ~was_safety
                to_refuse = cloud_mask & was_safety
                decision[to_swarm] = SWARM
                decision[to_local] = LOCAL
                decision[to_refuse] = REFUSE
                answered[to_refuse] = False
                fc["degraded_to_swarm"] += int(to_swarm.sum())
                fc["degraded_to_local"] += int(to_local.sum())
                fc["degraded_refused"] += int(to_refuse.sum())

        # --- refusals ---
        refuse_mask = decision == REFUSE
        answers[refuse_mask] = REFUSAL

        fc["breaker_opened"] = self.breaker.opened_count - brk0
        eng1 = self._member_counters()
        for k in _ENGINE_FAULT_KEYS:
            fc[k] = eng1[k] - eng0[k]
        correct = np.array([is_correct(answers[i], queries[i].get("gold"))
                            for i in range(B)])
        return GatewayLog(
            decision=decision, u=u, safety=s, latency=latency, cost=cost,
            prompt_len=plen,
            category=[q.get("category", "easy") for q in queries],
            correct=correct, answers=answers, consensus=consensus,
            faults=fc, answered=answered)

    def _member_counters(self) -> dict:
        """Sum of the swarm engines' failure counters (delta-tracked per
        batch so GatewayLog.faults reports this batch's events only)."""
        tot = dict.fromkeys(_ENGINE_FAULT_KEYS, 0)
        for m in self.swarm.members:
            for k in _ENGINE_FAULT_KEYS:
                tot[k] += m.counters.get(k, 0)
        return tot


# ---------------------------------------------------------------------------
# Baseline architectures (Sec. VI-B)
# ---------------------------------------------------------------------------

def run_edge_only(queries, engine: InferenceEngine, sim: NetworkSimulator,
                  max_new: int = 8, seed: int = 0,
                  stop_token: int | None = None) -> GatewayLog:
    """Edge-only baseline (Table III/IV row 1).

    ``stop_token`` must be the same stop token the gateway's swarm uses so
    the baseline is graded on *identically normalised* answers: the gateway
    truncates every answer at the first stop token before clustering and
    grading, and a baseline graded on raw tokens would count (or miss) gold
    entities in the post-answer continuation — a different metric, not a
    different architecture.
    """
    prompts = pad_prompts([q["prompt"] for q in queries])
    plen = (prompts != 0).sum(axis=1)
    res = engine.generate(prompts, max_new, seed=seed)
    answers = truncate_at_stop(res["tokens"], stop_token)
    lat = sim.edge_latency(plen + max_new)
    correct = np.array([is_correct(answers[i], q.get("gold"))
                        for i, q in enumerate(queries)])
    B = len(queries)
    return GatewayLog(
        decision=np.full((B,), LOCAL), u=res["u"],
        safety=np.zeros((B,)), latency=lat, cost=np.zeros((B,)),
        prompt_len=plen, category=[q.get("category", "easy") for q in queries],
        correct=correct, answers=answers,
        consensus=np.full((B,), np.nan))


def run_cloud_only(queries, cloud: InferenceEngine, sim: NetworkSimulator,
                   cost_params: cm.CostParams | None = None,
                   max_new: int = 8, seed: int = 0,
                   stop_token: int | None = None) -> GatewayLog:
    """Cloud-only baseline — answers normalised exactly like the gateway's
    (see ``run_edge_only`` on why grading raw tokens would skew Table IV)."""
    cost_params = cost_params or cm.CostParams()
    prompts = pad_prompts([q["prompt"] for q in queries])
    plen = (prompts != 0).sum(axis=1)
    res = cloud.generate(prompts, max_new, seed=seed)
    answers = truncate_at_stop(res["tokens"], stop_token)
    lat = sim.cloud_latency(plen + max_new)
    cost = np.asarray(cm.cost_cloud(jnp.asarray(plen, jnp.float32),
                                    float(max_new), cost_params))
    correct = np.array([is_correct(answers[i], q.get("gold"))
                        for i, q in enumerate(queries)])
    B = len(queries)
    return GatewayLog(
        decision=np.full((B,), CLOUD), u=res["u"],
        safety=np.zeros((B,)), latency=lat, cost=cost,
        prompt_len=plen, category=[q.get("category", "easy") for q in queries],
        correct=correct, answers=answers,
        consensus=np.full((B,), np.nan))
