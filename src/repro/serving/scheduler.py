"""Serving-side scheduling: peer selection, quorum, continuous batching.

* ``select_peers``: deadline-aware peer choice (paper objective O1 /
  Sec. IV-F) — rank peers by predicted L_edge + L_comm (Eq. 8-9 terms)
  and take the k that fit the L_max deadline.  Inputs: (n,) predicted
  latencies; output: (n,) bool mask of chosen peers.
* ``ContinuousBatcher``: fixed-slot decode batching — requests stream into
  free slots, finished slots free immediately (vLLM-style iteration-level
  scheduling, shaped for the batched TPU decode step whose batch dim is
  static).  Drives the ``InferenceEngine.serve`` lifecycle documented in
  docs/RUNTIME.md: admit -> prefill slot -> scanned decode chunk ->
  retire at stop token / max_new.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def select_peers(pred_latency: np.ndarray, k: int, l_max: float,
                 available: np.ndarray | None = None,
                 health: object | None = None) -> np.ndarray:
    """pred_latency (n,) predicted per-peer response time -> bool mask of
    up-to-k chosen peers whose prediction fits the deadline.

    ``health`` (optional ``faults.HealthRegistry``) refines selection:
    unhealthy peers are excluded until their next half-open recovery
    probe (``health.available()``), and a peer's observed EWMA latency
    replaces the static prediction where one has been recorded — a
    chronically slow peer stops being chosen even while nominally up."""
    n = len(pred_latency)
    if available is None:
        available = np.ones((n,), bool)
    if health is not None:
        available = available & health.available()
        ewma = np.asarray(health.ewma, float)
        pred_latency = np.where(np.isnan(ewma), pred_latency, ewma)
    order = np.argsort(pred_latency)
    chosen = np.zeros((n,), bool)
    taken = 0
    for j in order:
        if taken >= k:
            break
        if available[j] and pred_latency[j] <= l_max:
            chosen[j] = True
            taken += 1
    return chosen


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list                 # new-span tokens (the WHOLE prompt when
    max_new: int                 # state is None; only the new turn with one)
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    # warm-cache session handle (serving/engine.py::SessionState): admission
    # splices the live cache into the slot and continuation-prefills only
    # ``prompt`` instead of re-absorbing the whole conversation.  On a
    # paged engine, MANY queued requests may carry the SAME absorbed handle
    # — each admission is a refcounted block-table copy off it (COW tail),
    # fanning one prefilled prefix out across slots.
    state: object | None = None
    # hand back this request's SessionState at retirement (multi-turn serve)
    return_state: bool = False
    # admission ordering (multi-tenant serve): earliest deadline first,
    # then lowest priority value; FIFO among equals.  deadline_ms is an
    # absolute caller-defined clock (only compared between requests).
    priority: int = 0
    deadline_ms: float | None = None
    # set True when the scheduler gave up on the request (deadline expired
    # mid-queue/mid-decode, or famine shed) — it retires with whatever it
    # had; the gateway's cloud path is the recourse for shed work.
    shed: bool = False
    # full-conversation prompt for a warm request: if its session handle
    # is lost (eviction, slot failure), serve() transparently re-admits it
    # COLD from this prompt instead of failing the request.
    cold_prompt: list | None = None


class ContinuousBatcher:
    """Iteration-level scheduler over a fixed number of decode slots.

    Admission is deadline/priority-aware: the queue is drained earliest-
    ``deadline_ms`` first (requests without a deadline sort last), ties
    broken by ascending ``priority`` and then submit order — a tight-
    deadline request submitted late preempts the queue for the next free
    slot (it never preempts a request already decoding in a slot)."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        # failure-domain accounting (see docs/RUNTIME.md "Failure
        # semantics"): expired = deadline passed, shed = famine overflow,
        # requeued = slot died mid-decode and the request went back in line.
        self.counters = {"expired": 0, "shed": 0, "requeued": 0}

    def submit(self, req: Request):
        self.queue.append(req)

    @staticmethod
    def _urgency(r: Request) -> tuple:
        return (r.deadline_ms if r.deadline_ms is not None else float("inf"),
                r.priority)

    def admit(self, fits=None) -> list[int]:
        """Fill free slots from the queue in earliest-deadline-then-priority
        order; returns newly admitted slot ids.  ``fits`` (optional
        predicate) lets the cache pool veto admissions that cannot get
        blocks yet — vetoed requests stay queued, in order, and are retried
        once retirements free resources."""
        admitted = []
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                self.queue.sort(key=self._urgency)    # stable: FIFO ties
                j = next((jj for jj, r in enumerate(self.queue)
                          if fits is None or fits(r)), None)
                if j is None:
                    break
                self.slots[i] = self.queue.pop(j)
                admitted.append(i)
        return admitted

    def active_mask(self) -> np.ndarray:
        return np.array([s is not None and not s.done for s in self.slots])

    def active(self) -> list[tuple[int, Request]]:
        """(slot id, request) pairs currently decoding."""
        return [(i, s) for i, s in enumerate(self.slots)
                if s is not None and not s.done]

    def drain_finished(self) -> list[Request]:
        """Pop and return requests finished since the last drain."""
        out, self.finished = self.finished, []
        return out

    def record_tokens(self, tokens: np.ndarray, stop_token: int | None = None):
        """tokens (n_slots,) newest token per slot; retire finished requests."""
        for i, s in enumerate(self.slots):
            if s is None or s.done:
                continue
            t = int(tokens[i])
            s.generated.append(t)
            if len(s.generated) >= s.max_new or (stop_token is not None
                                                 and t == stop_token):
                s.done = True
                self.finished.append(s)
                self.slots[i] = None

    def expire(self, now_ms: float) -> list[tuple[int | None, Request]]:
        """Retire requests whose ``deadline_ms`` has passed at ``now_ms``.

        Queued requests are dropped before ever taking a slot; active
        requests are retired mid-decode with whatever tokens they have
        (slot freed immediately).  Both come back marked ``shed=True``
        through ``drain_finished``.  Returns ``(slot_id | None, request)``
        pairs so the engine can release pool resources of active
        casualties (queued ones hold none)."""
        out: list[tuple[int | None, Request]] = []
        for j in range(len(self.queue) - 1, -1, -1):
            r = self.queue[j]
            if r.deadline_ms is not None and r.deadline_ms < now_ms:
                self.queue.pop(j)
                out.append((None, r))
        for i, s in enumerate(self.slots):
            if (s is not None and not s.done and s.deadline_ms is not None
                    and s.deadline_ms < now_ms):
                out.append((i, s))
                self.slots[i] = None
        for _, r in out:
            r.done = True
            r.shed = True
            self.finished.append(r)
            self.counters["expired"] += 1
        return out

    def shed_one(self) -> Request | None:
        """Drop the least-urgent queued request (famine overflow control):
        latest deadline, then highest priority value, then latest arrival.
        It retires ``shed=True`` with no tokens — the caller decides the
        recourse (the gateway reroutes shed work to the cloud path)."""
        if not self.queue:
            return None
        j = max(range(len(self.queue)),
                key=lambda jj: (self._urgency(self.queue[jj]), jj))
        r = self.queue.pop(j)
        r.done = True
        r.shed = True
        self.finished.append(r)
        self.counters["shed"] += 1
        return r

    def requeue(self, i: int) -> Request | None:
        """Put slot ``i``'s request back in the queue (slot failure):
        decode progress is lost, but a still-valid warm handle means
        re-admission costs one continuation prefill, not a full one."""
        r = self.slots[i]
        if r is None:
            return None
        self.slots[i] = None
        r.generated = []
        r.done = False
        self.queue.append(r)
        self.counters["requeued"] += 1
        return r

    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)
