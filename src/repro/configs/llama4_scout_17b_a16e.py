"""Llama-4-Scout-17B-16E-style MoE [hf:meta-llama/Llama-4-Scout-17B-16E].

16 routed experts, top-1, plus one always-on shared expert, every layer.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=202048,
        num_experts=16, num_shared_experts=1, top_k=1, expert_d_ff=8192,
        rope_theta=500_000.0, capacity_factor=1.25,
        # top-1 routing collides easily — keep serving dispatch drop-free
        # (None => per-position capacity = batch size, exact top-1)
        moe_serve_capacity_factor=None,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=96, vocab_size=128,
        num_experts=4, num_shared_experts=1, top_k=1, expert_d_ff=96,
        attn_q_block=32, attn_kv_block=32,
    )
