"""DeepSeekMoE-16B [arXiv:2401.06066]: fine-grained 64 routed top-6 + 2 shared.

First layer dense (d_ff 10944), remaining 27 layers MoE with expert_d_ff 1408.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=10944, vocab_size=102400,
        num_experts=64, num_shared_experts=2, top_k=6, expert_d_ff=1408,
        first_k_dense=1, capacity_factor=1.25,
        # serving-path dispatch stays drop-free exact top-k (None): per-
        # position groups are batch-sized, so the buffer is small anyway;
        # set a float (e.g. 1.25) to bound it for very large serve batches
        # at the cost of the stepwise-parity guarantee (docs/RUNTIME.md).
        moe_serve_capacity_factor=None,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-smoke", family="moe",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=160, vocab_size=128,
        num_experts=8, num_shared_experts=2, top_k=2, expert_d_ff=32,
        first_k_dense=1, attn_q_block=32, attn_kv_block=32,
    )
