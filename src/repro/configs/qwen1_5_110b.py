"""Qwen1.5-110B-style dense GQA decoder [hf:Qwen/Qwen1.5-*]: QKV bias."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=49152, vocab_size=152064,
        attn_bias=True, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=128,
        attn_bias=True, attn_q_block=32, attn_kv_block=32,
    )
