"""Paper-prototype edge SLM tier (~1B, TinyLlama/Qwen2.5-1.5B class).

Used by the swarm serving examples as a heterogeneous peer alongside
smollm-135m (probe) and llama3-8b (gateway/on-prem FM).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="swarm-edge-1b", family="dense",
        num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
        head_dim=64, d_ff=5632, vocab_size=32000,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="swarm-edge-1b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=128,
        attn_q_block=32, attn_kv_block=32,
    )
