"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Backbone-only per the assignment: the anyres vision tower is a STUB;
``input_specs`` supplies precomputed patch embeddings (576 tokens = one
24x24 tile) that are concatenated ahead of the text tokens.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=32000,
        rope_theta=1_000_000.0,
        frontend="vision_patches", frontend_tokens=576,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llava-next-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=160, vocab_size=128,
        frontend="vision_patches", frontend_tokens=16,
        attn_q_block=32, attn_kv_block=32,
    )
