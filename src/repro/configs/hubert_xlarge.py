"""HuBERT-XLarge [arXiv:2106.07447]: 48L encoder-only audio backbone.

The conv waveform frontend is a STUB per the assignment: ``input_specs``
supplies precomputed frame embeddings at d_model.  Vocab 504 = masked-unit
(cluster) prediction head.  No decode step (encoder-only) — decode shapes
are skipped (DESIGN.md §4).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
        head_dim=80, d_ff=5120, vocab_size=504,
        causal=False, ffn_act="gelu", frontend="audio_frames",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="audio",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=64,
        causal=False, ffn_act="gelu", frontend="audio_frames",
        attn_q_block=32, attn_kv_block=32,
    )
