"""Command-R+-104B-style dense GQA [hf:CohereForAI/c4ai-command-r-*]: no bias."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b", family="dense",
        num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
        head_dim=128, d_ff=33792, vocab_size=256000,
        rope_theta=75_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=128,
        attn_q_block=32, attn_kv_block=32,
    )
