"""RecurrentGemma-2B [arXiv:2402.19427]: RG-LRU + local attention, 1:2.

26 layers = 8 x (rglru, rglru, local-attn) + (rglru, rglru) remainder; KV is
bounded by the 2048 window, so long_500k decode runs.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        head_dim=256, d_ff=7680, vocab_size=256000,
        mixer_pattern=("rglru", "rglru", "attn_local"),
        window=2048, rnn_width=2560, rnn_conv_width=4,
        ffn_act="geglu", tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke", family="hybrid",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=128,
        mixer_pattern=("rglru", "rglru", "attn_local"),
        window=32, rnn_width=64, rnn_conv_width=4,
        ffn_act="geglu", tie_embeddings=True,
        attn_q_block=32, attn_kv_block=32,
    )
