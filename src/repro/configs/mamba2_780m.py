"""Mamba2-780M [arXiv:2405.21060]: attention-free SSD, O(1) decode state.

The designated long_500k swarm member: decode cost is independent of context.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
        head_dim=0, d_ff=0, vocab_size=50280,
        mixer_pattern=("ssd",), tie_embeddings=True,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_ngroups=1,
        ssm_conv_width=4, ssm_chunk=256,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
        head_dim=0, d_ff=0, vocab_size=128,
        mixer_pattern=("ssd",), tie_embeddings=True,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_ngroups=1,
        ssm_conv_width=4, ssm_chunk=32,
    )
