"""Architecture registry, assigned input shapes, and abstract input specs.

Every assigned architecture is selectable via ``--arch <id>`` (dashes or
underscores).  ``input_specs`` returns ShapeDtypeStruct stand-ins only — the
dry-run never allocates real parameters or activations.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

ARCH_IDS = (
    "hubert-xlarge",
    "qwen1.5-110b",
    "smollm-135m",
    "llama3-8b",
    "command-r-plus-104b",
    "mamba2-780m",
    "recurrentgemma-2b",
    "llama4-scout-17b-a16e",
    "deepseek-moe-16b",
    "llava-next-mistral-7b",
    # paper's own swarm prototype tiers (edge SLM / gateway / cloud FM)
    "swarm-edge-1b",
)


def _module(arch: str):
    name = arch.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).smoke()


# ---------------------------------------------------------------------------
# Assigned input shapes (LM-family: seq_len x global_batch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicability(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    """None if the (arch, shape) cell runs; else the skip reason."""
    if cfg.family in ("encoder", "audio") and shape.kind == "decode":
        return "encoder-only: no decode step"
    if shape.name == "long_500k" and not _subquadratic(cfg):
        return "full quadratic attention: long_500k needs sub-quadratic"
    return None


def _subquadratic(cfg: ModelConfig) -> bool:
    kinds = {m for m, _ in cfg.layer_plan()}
    return "attn" not in kinds  # ssd / rglru / attn_local only


def cells(include_skipped: bool = False):
    """All (arch, shape) cells for the assigned matrix."""
    out = []
    for arch in ARCH_IDS[:10]:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            skip = applicability(cfg, shape)
            if skip is None or include_skipped:
                out.append((arch, shape.name, skip))
    return out


# ---------------------------------------------------------------------------
# Abstract input specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: full-sequence batch; decode: one new token + cache specs
    (the cache itself comes from ``jax.eval_shape`` over ``init_cache``).
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch: dict[str, Any] = {}
        if cfg.family in ("encoder", "audio"):
            batch["frontend_embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
            s_text = S
        elif cfg.frontend == "vision_patches":
            F = cfg.frontend_tokens
            batch["frontend_embeds"] = _sds((B, F, cfg.d_model), jnp.bfloat16)
            batch["tokens"] = _sds((B, S - F), jnp.int32)
            s_text = S - F
        else:
            batch["tokens"] = _sds((B, S), jnp.int32)
            s_text = S
        if shape.kind == "train":
            batch["labels"] = _sds((B, s_text), jnp.int32)
            batch["loss_mask"] = _sds((B, s_text), jnp.float32)
        return batch
    # decode
    from repro.models.transformer import init_cache
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "index": _sds((B,), jnp.int32),
        "cache": cache,
    }
