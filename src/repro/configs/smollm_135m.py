"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M]: llama-arch small, tied embed.

Doubles as the paper's probe/edge SLM tier in the swarm prototype.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", family="dense",
        num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
        head_dim=64, d_ff=1536, vocab_size=49152,
        tie_embeddings=True, rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m-smoke", family="dense",
        num_layers=2, d_model=48, num_heads=3, num_kv_heads=1,
        head_dim=16, d_ff=96, vocab_size=128,
        tie_embeddings=True, attn_q_block=32, attn_kv_block=32,
    )
