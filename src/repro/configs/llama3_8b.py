"""Llama-3-8B [arXiv:2407.21783]: dense GQA, 128k vocab."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=128256,
        rope_theta=500_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=160, vocab_size=128,
        attn_q_block=32, attn_kv_block=32,
    )
