"""Quantized storage for serving: per-row scaled int8/fp8 tensors.

Two consumers share the same scheme:

* **KV block pools** (``cache_quant``): each cache row — one (slot,
  kv-head) pair, ``head_dim`` wide — is stored as int8 / float8_e4m3fn
  plus one float32 scale, computed as ``amax(row) / qmax``.  Scales
  live alongside the pool as a parallel pytree leaf (``KVCache.k_scale``
  / ``.v_scale``); the decode paths never materialise the dequantized
  pool — they fold the k-scale into the post-QK scores and the v-scale
  into the softmax weights inside the accumulator (see
  ``attention._decode_stream_chunk`` and ``kernels/decode_attention``).
* **Serving weights** (``weight_quant``): matmul weights are stored as
  a :class:`QTensor` — quantized payload + per-row f32 scale — whose
  ``.astype`` dequantizes on the fly, so every ``p["w"].astype(dt)``
  call site works unchanged.  Per-last-dim row scaling follows the
  quantized-EMA bookkeeping idiom (olmax ``optimizer.py``).

Quantizing a freshly-zeroed row yields ``(0, scale=0)`` and
dequantizing with a zero scale yields zeros, so reset blocks and
quantize(scatter) agree without special cases.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

# max representable magnitude per storage format: int8 symmetric
# (+-127, -128 unused), float8 e4m3fn (+-448, the largest normal)
QMAX = {"int8": 127.0, "fp8": 448.0}
CACHE_QUANTS = (None, "int8", "fp8")


def qdtype(quant: str):
    """Storage dtype for a quantization mode name."""
    if quant == "int8":
        return jnp.int8
    if quant == "fp8":
        return jnp.float8_e4m3fn
    raise ValueError(f"unknown quantization mode {quant!r}; "
                     f"expected one of {CACHE_QUANTS[1:]}")


def check_quant(quant):
    if quant not in CACHE_QUANTS:
        raise ValueError(f"unknown quantization mode {quant!r}; "
                         f"expected one of {CACHE_QUANTS}")
    return quant


def quantize_rows(x, quant: str):
    """``x (..., D) -> (q (..., D), scale (...))``: symmetric per-row
    quantization with the scale over the trailing dim, in f32."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = (amax / QMAX[quant]).astype(jnp.float32)
    y = x.astype(jnp.float32) / jnp.where(scale > 0, scale, 1.0)[..., None]
    if quant == "int8":
        q = jnp.clip(jnp.round(y), -127.0, 127.0).astype(jnp.int8)
    else:
        q = y.astype(jnp.float8_e4m3fn)
    return q, scale


def dequantize_rows(q, scale, dtype):
    """Materialised per-row dequant — the gathered-view oracle path.

    The fused decode paths do NOT call this on pool-shaped values; they
    apply the scale inside the softmax accumulator instead.  ``dtype``
    is the cache/compute dtype (bf16), never f32 (see the swarmlint
    ``quant-scale-drift`` rule)."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)  # swarmlint: ignore[quant-scale-drift] the one sanctioned dequant helper; callers pass the cache dtype and the rule polices them


# ---------------------------------------------------------------------------
# weight storage


class QTensor(NamedTuple):
    """Quantized weight + per-row (trailing-dim) f32 scale.

    A NamedTuple is a native pytree, so QTensor leaves flow through
    ``device_put`` / scan stacking / ``jax.tree`` ops transparently;
    ``.astype(dt)`` dequantizes at the matmul call sites."""
    q: Any
    scale: Any

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self):
        return self.q.ndim

    def astype(self, dtype):
        return dequantize_rows(self.q, self.scale, dtype)

    def take_rows(self, idx, dtype):
        """Gather leading-dim rows quantized, dequantize AFTER the
        gather — k/E bytes for the MoE gather-decode variant."""
        return dequantize_rows(jnp.take(self.q, idx, axis=0),
                               jnp.take(self.scale, idx, axis=0), dtype)


def quantize_tensor(w, quant: str) -> QTensor:
    return QTensor(*quantize_rows(w, quant))


# matmul weights worth quantizing.  Deliberately absent: embed / norms /
# biases (tiny, numerically load-bearing), router logits (routing flips
# are catastrophic vs a few mantissa bits saved), and every recurrent
# mixer weight (rg-lru / ssd recurrences compound per-step error — same
# reason their state rows stay bf16 in the cache pool).
_QUANT_KEYS = frozenset({
    "wq", "wk", "wv", "wo",                  # attention projections
    "w_up", "w_down", "w_gate",              # dense + expert MLPs
    "lm_head",                               # untied output head
})


def _quantize_subtree(tree, quant: str):
    if isinstance(tree, dict):
        return {k: (quantize_tensor(v, quant)
                    if k in _QUANT_KEYS and not isinstance(v, dict)
                    else _quantize_subtree(v, quant))
                for k, v in tree.items()}
    return tree


def quantize_params(params, quant: str):
    """Quantize the serving weights (attention/MLP/MoE matmuls + the
    untied lm_head) to ``quant`` storage; everything else passes
    through untouched.  Works on stacked (scan-over-layers) stages —
    the leading repeat dim just becomes part of the row batch."""
    check_quant(quant)
    out = dict(params)
    out["stages"] = [_quantize_subtree(sc, quant) for sc in params["stages"]]
    if "lm_head" in out:
        out["lm_head"] = quantize_tensor(out["lm_head"], quant)
    return out


def quantize_param_axes(axes, params):
    """Mirror ``quantize_params`` over a logical-axes tree so sharding
    specs stay structurally parallel: a QTensor param leaf gets
    ``QTensor(q=<orig axes>, scale=<orig axes minus trailing dim>)``."""
    def walk(a, p):
        if isinstance(p, QTensor):
            return QTensor(q=a, scale=a[:-1])
        if isinstance(p, dict):
            return {k: walk(a[k], v) for k, v in p.items()}
        if isinstance(p, (list, tuple)) and not _is_axes_leaf(p):
            return type(p)(walk(ae, pe) for ae, pe in zip(a, p))
        return a

    def _is_axes_leaf(x):
        return isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x)

    return walk(axes, params)
