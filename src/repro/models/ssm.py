"""Mamba-2 (SSD, state-space duality) block — chunked parallel form + decode.

Chunked SSD [arXiv:2405.21060, Listing 1], with the inter-chunk recurrence as
a ``lax.scan`` (linear memory in chunk count, and it reuses the same scan
machinery the rest of the stack compiles well).  Decode is the O(1) recurrent
step on a (B, H, P, N) f32 state — this is why mamba2 is the designated
long_500k swarm member (DESIGN.md §4).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import (ModelConfig, ParamDef, norm_def, normal_init,
                                 ones_init, rmsnorm, zeros_init)

Array = jax.Array


class SSMState(NamedTuple):
    ssd: Array     # (B, H, P, N) f32
    conv: Array    # (B, W-1, conv_dim)


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_d_inner
    H = cfg.ssm_nheads
    P = cfg.ssm_head_dim
    G = cfg.ssm_ngroups
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * G * N
    d_in_proj = 2 * d_inner + 2 * G * N + H
    return d_inner, H, P, G, N, conv_dim, d_in_proj


def ssd_defs(cfg: ModelConfig) -> dict:
    d_inner, H, P, G, N, conv_dim, d_in_proj = _dims(cfg)
    D = cfg.d_model

    def a_init(key, shape, dtype):
        # A in [1, 16] (mamba2 default) -> A_log
        return jnp.log(jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
                       ).astype(dtype)

    def dt_init(key, shape, dtype):
        dt = jnp.exp(jax.random.uniform(key, shape, jnp.float32)
                     * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
        # inverse softplus
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)

    return {
        "norm": norm_def(D),
        "in_proj": ParamDef((D, d_in_proj), ("embed", "ssm_inner"), normal_init()),
        "conv_w": ParamDef((cfg.ssm_conv_width, conv_dim), ("conv_width", "ssm_inner"), normal_init()),
        "conv_b": ParamDef((conv_dim,), ("ssm_inner",), zeros_init),
        "A_log": ParamDef((H,), ("heads",), a_init, jnp.float32),
        "dt_bias": ParamDef((H,), ("heads",), dt_init, jnp.float32),
        "D_skip": ParamDef((H,), ("heads",), ones_init, jnp.float32),
        "gnorm": ParamDef((d_inner,), ("ssm_inner",), zeros_init),
        "out_proj": ParamDef((d_inner, D), ("ssm_inner", "embed"),
                             normal_init(0.02 / (2 * cfg.num_layers) ** 0.5)),
    }


def _split_proj(zxbcdt: Array, cfg: ModelConfig):
    d_inner, H, P, G, N, conv_dim, _ = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt = zxbcdt[..., -H:]
    return z, xBC, dt


def _causal_conv(xBC: Array, w: Array, b: Array, prev: Array | None = None,
                 tail_index: Array | None = None):
    """Depthwise causal conv1d. xBC (B,L,C); w (W,C); returns (out, new_tail).

    ``tail_index`` (B,) — number of *real* (non-padding) leading columns per
    row.  Default (None) takes the tail from the last W-1 columns, which is
    correct for LEFT-padded spans (real tokens at the end).  Continuation
    spans are RIGHT-padded (real tokens first, so the conv window of the
    first real token reaches into ``prev`` — the cached context tail — with
    no padding gap); there the tail must end at the last real input, i.e.
    padded-input columns [tail_index, tail_index + W - 2]."""
    B, L, C = xBC.shape
    W = w.shape[0]
    if prev is None:
        prev = jnp.zeros((B, W - 1, C), xBC.dtype)
    xpad = jnp.concatenate([prev, xBC], axis=1)
    out = jax.lax.conv_general_dilated(
        xpad, w[:, None, :].astype(xBC.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C)
    out = jax.nn.silu(out + b.astype(out.dtype))
    if W <= 1:
        tail = jnp.zeros((B, 0, C), xBC.dtype)
    elif tail_index is None:
        tail = xpad[:, -(W - 1):]
    else:
        idx = tail_index[:, None] + jnp.arange(W - 1, dtype=jnp.int32)[None]
        tail = jnp.take_along_axis(xpad, idx[..., None], axis=1)
    return out, tail


def _causal_conv_step(x: Array, w: Array, b: Array, prev: Array):
    """One-token depthwise causal conv (decode path). x (B,1,C); prev
    (B,W-1,C).  Same math as ``_causal_conv`` at L=1, but lowered as a
    window multiply+sum instead of ``conv_general_dilated``: the per-step
    conv op is pure overhead at L=1, and XLA CPU's SPMD partitioner
    miscompiles (native crash) the grouped conv when C is sharded over
    'model' while the batch dim is replicated — the sharded decode scan
    hits exactly that layout whenever B doesn't divide the 'data' axis."""
    B, _, C = x.shape
    W = w.shape[0]
    xpad = jnp.concatenate([prev, x], axis=1)            # (B, W, C)
    # f32 window accumulation, rounded back to the activation dtype before
    # bias+silu — the same numerics the conv lowering produces
    out = (xpad.astype(jnp.float32) * w.astype(jnp.float32)[None]).sum(
        axis=1, keepdims=True).astype(x.dtype)
    out = jax.nn.silu(out + b.astype(out.dtype))
    tail = xpad[:, 1:] if W > 1 else jnp.zeros((B, 0, C), x.dtype)
    return out, tail


def _segsum(a: Array) -> Array:
    """a (..., q) -> (..., q, q) with out[i,j] = sum a[j+1..i], -inf above diag."""
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    d = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_scan(x: Array, dt: Array, A: Array, Bm: Array, Cm: Array, chunk: int,
             init_state: Array | None = None):
    """Chunked SSD.

    x (B,L,H,P); dt (B,L,H) (post-softplus); A (H,) negative;
    Bm, Cm (B,L,G,N).  Returns y (B,L,H,P), final state (B,H,P,N) f32.
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, L)
    assert L % Q == 0
    nc = L // Q

    xb = (x * dt[..., None]).astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    a = (dt * A[None, None, :]).astype(jnp.float32)           # (B,L,H) log-decay
    ab = a.reshape(Bsz, nc, Q, H).transpose(0, 3, 1, 2)       # (B,H,nc,Q)
    a_cum = jnp.cumsum(ab, axis=-1)
    Bb = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, G, N)
    Cb = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, G, N)

    def rep_heads(t):  # (B,nc,Q,G,N) -> (B,nc,Q,H,N)
        return jnp.repeat(t, rep, axis=3)

    Bh, Ch = rep_heads(Bb), rep_heads(Cb)

    # 1. intra-chunk (diagonal blocks)
    Ldec = jnp.exp(_segsum(ab))                               # (B,H,nc,Q,Q)
    scores = jnp.einsum("bclhn,bcshn->bhcls", Ch, Bh)         # (B,H,nc,Q,Q)
    y_diag = jnp.einsum("bhcls,bhcls,bcshp->bclhp", scores, Ldec, xb)

    # 2. per-chunk input states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)           # (B,H,nc,Q)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bh, decay_states, xb)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(a_cum[..., -1])                     # (B,H,nc)

    def step(s, inp):
        st_c, dec_c = inp                                     # (B,H,P,N), (B,H)
        s_out = s                                             # state *entering* chunk
        s = s * dec_c[..., None, None] + st_c
        return s, s_out

    s0 = (init_state if init_state is not None
          else jnp.zeros((Bsz, H, P, N), jnp.float32))
    final, prev_states = jax.lax.scan(
        step, s0, (states.swapaxes(0, 1), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                  # (B,nc,H,P,N)

    # 4. state -> output contribution
    out_decay = jnp.exp(a_cum)                                # (B,H,nc,Q)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Ch, prev_states, out_decay)

    y = (y_diag + y_off).reshape(Bsz, L, H, P)
    return y, final


def ssd_block(p: dict, x: Array, cfg: ModelConfig) -> Array:
    """Full-sequence mamba2 block. x (B,S,D)."""
    d_inner, H, P, G, N, conv_dim, _ = _dims(cfg)
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"].astype(h.dtype)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    xBC, _ = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + G * N].reshape(*xs.shape[:2], G, N)
    Cm = xBC[..., d_inner + G * N:].reshape(*xs.shape[:2], G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_scan(xs.reshape(*xs.shape[:2], H, P), dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xs.reshape(*xs.shape[:2], H, P).astype(jnp.float32) * p["D_skip"][:, None]
    y = y.reshape(*xs.shape[:2], d_inner)
    y = rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                p["gnorm"], cfg.norm_eps)
    return x + y @ p["out_proj"].astype(x.dtype)


def ssd_prefill(p: dict, x: Array, state: SSMState, positions: Array,
                cfg: ModelConfig, mesh=None, rules=None, *,
                continuation: bool = False) -> tuple[Array, SSMState]:
    """Prompt absorption: chunked SSD scan that also returns the carried
    (B,H,P,N) state and conv tail for decode.

    positions (B,S): negative positions are inert bucket padding — their
    conv input is zeroed and dt forced to 0, so the step decay is exp(0)=1
    and the input contribution x*dt vanishes; the carried state passes
    through untouched.  Cold spans are left-padded (last column real);
    ``continuation=True`` spans are RIGHT-padded — real tokens first, so
    the conv window crosses from ``state.conv`` (the cached context tail)
    straight into the new span with no padding gap, and the conv tail is
    taken at the last *real* column.  The recurrence itself is
    layout-agnostic: ``state.ssd`` folds in as the scan's initial state and
    padding steps pass it through exactly (decay 1, input 0), so the final
    state equals the state after the last real token either way.
    """
    d_inner, H, P, G, N, conv_dim, _ = _dims(cfg)
    B, S, _ = x.shape
    valid = (positions >= 0)[..., None]                      # (B,S,1)
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"].astype(h.dtype)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    xBC = jnp.where(valid, xBC, 0)
    tail_index = (valid[..., 0].sum(axis=1).astype(jnp.int32)
                  if continuation else None)
    xBC, conv_tail = _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                  prev=state.conv, tail_index=tail_index)
    xs = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + G * N].reshape(B, S, G, N)
    Cm = xBC[..., d_inner + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = jnp.where(valid, dt, 0.0)
    A = -jnp.exp(p["A_log"])
    y, s_final = ssd_scan(xs.reshape(B, S, H, P), dt, A, Bm, Cm,
                          cfg.ssm_chunk, init_state=state.ssd)
    y = y + xs.reshape(B, S, H, P).astype(jnp.float32) * p["D_skip"][:, None]
    y = y.reshape(B, S, d_inner)
    y = rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                p["gnorm"], cfg.norm_eps)
    state = SSMState(
        ssd=constrain(s_final, ("act_batch", "act_heads", None, None),
                      mesh, rules),
        conv=constrain(conv_tail, ("act_batch", None, "act_ssm_inner"),
                       mesh, rules))
    return x + y @ p["out_proj"].astype(x.dtype), state


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    d_inner, H, P, G, N, conv_dim, _ = _dims(cfg)
    return SSMState(
        # swarmlint: ignore[dtype-drift] the SSD state update decays per
        # token (dA * state + dBx); bf16 accumulation drifts over long
        # sequences and breaks paged-vs-monolithic bitwise parity
        ssd=jnp.zeros((batch, H, P, N), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), cfg.dtype),
    )


def ssd_decode(p: dict, x: Array, state: SSMState, cfg: ModelConfig,
               mesh=None, rules=None) -> tuple[Array, SSMState]:
    """One-token decode. x (B,1,D).  On-mesh the carried (B,H,P,N) state is
    pinned ``(act_batch, act_heads)``-sharded across the decode scan."""
    d_inner, H, P, G, N, conv_dim, _ = _dims(cfg)
    B = x.shape[0]
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"].astype(h.dtype)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    xBC, conv_tail = _causal_conv_step(xBC, p["conv_w"], p["conv_b"],
                                       state.conv)
    xs = xBC[:, 0, :d_inner]
    Bm = xBC[:, 0, d_inner:d_inner + G * N].reshape(B, G, N)
    Cm = xBC[:, 0, d_inner + G * N:].reshape(B, G, N)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                                      # (B,H)
    xh = (xs.reshape(B, H, P).astype(jnp.float32) * dt[..., None])
    rep = H // G
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)               # (B,H,N)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    s_new = state.ssd * dA[..., None, None] + xh[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", s_new, Ch)
    y = y + xs.reshape(B, H, P).astype(jnp.float32) * p["D_skip"][:, None]
    y = y.reshape(B, 1, d_inner)
    y = rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                p["gnorm"], cfg.norm_eps)
    out = x + y @ p["out_proj"].astype(x.dtype)
    state = SSMState(
        ssd=constrain(s_new, ("act_batch", "act_heads", None, None),
                      mesh, rules),
        conv=constrain(conv_tail, ("act_batch", None, "act_ssm_inner"),
                       mesh, rules))
    return out, state
