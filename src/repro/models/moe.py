"""Mixture-of-Experts FFN: capacity-based dispatch, EP-shardable.

Dispatch layout is *grouped*: tokens are reshaped into ``moe_groups`` groups
(one per data-parallel shard at the production mesh), each group dispatches
into its own (E, C_local) capacity buffer.  Scatter/gather indices then stay
aligned with the batch sharding, so SPMD keeps dispatch local to a (data,
model) shard pair — the only collectives are the ones real expert parallelism
needs (routed activations crossing the expert axis).

Supports DeepSeek-style shared experts (always-on dense branch) and top-k
renormalised softmax gating (top-1 == Switch, top-6 == DeepSeekMoE,
top-1+shared == Llama-4-Scout).

Three dispatch flavours share the router (``_route_topk``) and the sort-based
in-expert ranking (``_rank_in_expert``):

* ``moe_block`` — the training / full-forward path: flat token groups,
  capacity ``moe_capacity`` (tokens compete batch-wide; overflow drops).
* ``moe_prefill_block`` — the serving prefill path: **one dispatch group per
  prompt position**, so each group routes exactly the token set a stepwise
  ``decode_step`` would route, and fused prefill reproduces sequential
  absorption semantics by construction.  Inert bucket-padding tokens
  (negative positions) are *masked*: router logits forced to -inf, the
  assignment moved to a sentinel expert segment so it never consumes a
  capacity slot of a real expert, and the combine weight zeroed.  Per-group
  capacity defaults to the group size (drop-free => exact top-k); the
  ``moe_serve_capacity_factor`` config knob bounds it at scale.
* ``moe_decode_block`` — the serving decode path: the SAME per-position
  dispatch at S=1 (a one-token-column capacity buffer, constant shapes for
  the decode scan).  Sharing the dispatch structure is what makes fused
  prefill and stepwise absorption **bitwise identical** through MoE layers:
  XLA evaluates the batched dispatch einsums per group slice, so a position
  routed inside an (S, E, C, D) buffer produces the exact bits the same
  position routed alone would (verified; the alternative top-k weight
  gather — ``moe_decode_impl="gather"``, expert FLOPs k instead of E — is
  1 bf16 ulp off, enough to flip a greedy argmax on an exact tie).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

try:                                    # jax >= 0.5
    from jax import shard_map as _shard_map
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.distributed.sharding import constrain
from repro.models import quant as Q
from repro.models.common import (ACTIVATIONS, ModelConfig, ParamDef, norm_def,
                                 normal_init, rmsnorm)
from repro.models.ffn import _mlp_body, mlp_defs

Array = jax.Array


def moe_defs(cfg: ModelConfig) -> dict:
    D, E, Fe = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    std_o = 0.02 / (2 * cfg.num_layers) ** 0.5
    defs = {
        "norm": norm_def(D),
        "router": ParamDef((D, E), ("embed", "experts"), normal_init()),
        "w_gate": ParamDef((E, D, Fe), ("experts", "embed", "expert_ffn"), normal_init()),
        "w_up": ParamDef((E, D, Fe), ("experts", "embed", "expert_ffn"), normal_init()),
        "w_down": ParamDef((E, Fe, D), ("experts", "expert_ffn", "embed"), normal_init(std_o)),
    }
    if cfg.num_shared_experts:
        shared = dict(mlp_defs(cfg, d_ff=cfg.num_shared_experts * cfg.expert_d_ff))
        shared.pop("norm")  # share the block norm
        defs["shared"] = shared
    return defs


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def moe_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(_round_up(c, 8), 8)


def moe_serve_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    """Per-group capacity on the serving (prefill) path.

    Default (``moe_serve_capacity_factor=None``): the group size itself —
    a group can never overflow an expert, so serving routing is exact
    top-k (drop-free) and fused prefill matches stepwise absorption
    bitwise at the routing level.  With the factor set, capacity is
    bounded like the training dispatch (overflow tokens lose their slot),
    trading the exactness guarantee for an O(factor·k/E) smaller buffer
    at large serve batch sizes.
    """
    f = cfg.moe_serve_capacity_factor
    if f is None:
        return tokens_per_group
    c = int(tokens_per_group * cfg.top_k / cfg.num_experts * f)
    return max(min(_round_up(c, 8), tokens_per_group), 1)


_MASKED = -1e30          # "-inf" for masked router logits (softmax-safe)


def _route_topk(router: Array, h: Array, cfg: ModelConfig,
                valid: Array | None = None) -> tuple[Array, Array, Array]:
    """Top-k routing in f32: h (g,T,D) -> (gates (g,T,k), idx (g,T,k),
    probs (g,T,E)).  ``valid`` (g,T) masks inert tokens: their logits are
    forced to -inf (uniform probs, no NaN) — callers must also exclude
    them from capacity counts and zero their combine weights.
    """
    logits = jnp.einsum("gtd,de->gte", h.astype(jnp.float32),
                        router.astype(jnp.float32))
    if valid is not None:
        logits = jnp.where(valid[..., None], logits, _MASKED)
    probs = jax.nn.softmax(logits, axis=-1)                # (g,T,E)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)           # (g,T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def _rank_in_expert(flat_e: Array) -> Array:
    """flat_e (g, A) expert ids -> (g, A) position of each assignment within
    its expert's arrival order.  Sort-based ranking: O(A log A) and O(A)
    memory; argsort is stable, so in-segment order == token order == the
    GShard cumsum semantics.  Segment starts come from a cummax over
    boundary markers (a vmapped searchsorted segfaulted XLA:CPU under
    512-way SPMD — see §Perf)."""
    groups, A = flat_e.shape
    sort_idx = jnp.argsort(flat_e, axis=1)                 # (g, A)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    ar = jnp.arange(A)[None, :]
    is_new = jnp.concatenate(
        [jnp.ones((groups, 1), bool),
         sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    seg_start = jax.lax.cummax(jnp.where(is_new, ar, 0), axis=1)
    pos_sorted = ar - seg_start
    inv = jnp.argsort(sort_idx, axis=1)
    return jnp.take_along_axis(pos_sorted, inv, axis=1)    # (g, A)


def moe_block(p: dict, x: Array, cfg: ModelConfig, *,
              groups: int = 1, mesh=None, rules=None) -> tuple[Array, Array]:
    """x (B,S,D) -> (x + moe(x), aux_loss).  groups must divide B*S.

    Sharding note: the capacity buffer is kept REPLICATED over the model
    axis (constrained below) so the dispatch scatter and combine gather stay
    local to each (data, model) shard — if the buffer's E dim is
    model-sharded, XLA SPMD rewrites the 3-index scatter into dense
    select-updates with (A, D)-sized u32 index tensors (measured 58 GB of
    u32 on deepseek train_4k; §Perf iteration 3).  The expert einsums then
    contract against model-sharded weights and their outputs are constrained
    back to replicated — one (g,E,C,D)-sized all-gather per layer instead.
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    T = B * S
    assert T % groups == 0, (T, groups)
    Tg = T // groups
    C = moe_capacity(cfg, Tg)

    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    hf = h.reshape(groups, Tg, D)

    # --- routing (f32) ---
    gates, idx, probs = _route_topk(p["router"], hf, cfg)      # (g,Tg,k)

    # load-balance aux loss (Switch): E * mean_e(frac_tokens_e * mean_prob_e)
    onehot_top1 = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(jnp.mean(onehot_top1, axis=1) * jnp.mean(probs, axis=1))

    # --- dispatch: position of each assignment within its expert ---
    flat_e = idx.reshape(groups, Tg * k)                       # (g, A)
    A = Tg * k
    if cfg.moe_impl == "cumsum":
        # GShard-style one-hot cumsum: materialises (g, A, E) int32 —
        # measured 100+ GB/device at deepseek train_4k; kept for the
        # hillclimb before/after (EXPERIMENTS.md §Perf iteration 1)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (g, A, E)
        pos = jnp.cumsum(oh, axis=1) - oh
        pos = jnp.take_along_axis(
            pos, flat_e[..., None], axis=-1)[..., 0]           # (g, A)
    else:
        pos = _rank_in_expert(flat_e)                          # (g, A)
    keep = pos < C
    # dropped assignments scatter to row C (then sliced off)
    e_idx = jnp.where(keep, flat_e, E - 1)
    c_idx = jnp.where(keep, pos, C)

    token_src = jnp.repeat(jnp.arange(Tg), k)                  # (A,)
    src = jnp.take(hf, token_src, axis=1).astype(h.dtype)      # (g, A, D)

    def _dispatch(src_l, e_l, c_l):
        gl = jnp.broadcast_to(jnp.arange(src_l.shape[0])[:, None], e_l.shape)
        b = jnp.zeros((src_l.shape[0], E, C + 1, D), src_l.dtype)
        return b.at[gl, e_l, c_l].set(src_l, mode="drop")[:, :, :C]

    def _combine(ob_l, e_l, c_l):
        gl = jnp.broadcast_to(jnp.arange(ob_l.shape[0])[:, None], e_l.shape)
        return ob_l[gl, e_l, jnp.minimum(c_l, C - 1)]

    # Dispatch/combine run under shard_map when the group dim divides the
    # batch axes: each (data, model) shard then executes a purely LOCAL
    # scatter/gather with (A,)-sized indices.  Left to SPMD propagation, the
    # 3-index scatter on an expert-sharded buffer gets rewritten into dense
    # select-updates with (A, D)-sized u32 index maps (measured 58 GB of u32
    # temps on deepseek train_4k; §Perf iterations 1-3).
    daxes = tuple(a for a in ("pod", "data") if mesh is not None
                  and a in mesh.shape)
    dp = 1
    for a in daxes:
        dp *= mesh.shape[a]
    use_smap = mesh is not None and daxes and groups % dp == 0
    if use_smap:
        from jax.sharding import PartitionSpec as P
        gspec = P(daxes if len(daxes) > 1 else daxes[0])
        smap = lambda f: _shard_map(f, mesh=mesh,
                                    in_specs=(gspec, gspec, gspec),
                                    out_specs=gspec)
        buf = smap(_dispatch)(src, e_idx, c_idx)
    else:
        buf = _dispatch(src, e_idx, c_idx)

    # --- expert compute (weights model-sharded over E) ---
    act = ACTIVATIONS[cfg.ffn_act]
    dt = h.dtype
    gate_h = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt))
    up_h = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    out_buf = jnp.einsum("gecf,efd->gecd", act(gate_h) * up_h,
                         p["w_down"].astype(dt))               # (g,E,C,D)
    out_buf = constrain(out_buf, ("act_batch", None, None, None), mesh, rules)

    # --- combine ---
    if use_smap:
        y = smap(_combine)(out_buf, e_idx, c_idx)              # (g,A,D)
    else:
        y = _combine(out_buf, e_idx, c_idx)
    w = (gates.reshape(groups, Tg * k) * keep).astype(jnp.float32)
    y = (y.astype(jnp.float32) * w[..., None]).reshape(groups, Tg, k, D).sum(2)

    if "shared" in p:
        y = y + _mlp_body(p["shared"], hf, cfg).astype(jnp.float32)

    return x + y.reshape(B, S, D).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Serving path: masked per-position prefill + exact top-k decode
# ---------------------------------------------------------------------------

def moe_prefill_block(p: dict, x: Array, cfg: ModelConfig, positions: Array,
                      *, mesh=None, rules=None) -> tuple[Array, Array]:
    """Capacity-aware MASKED dispatch for the fused serving prefill.

    x (B,S,D); positions (B,S) absolute positions — negative marks inert
    bucket padding.  Returns (x + moe(x), aux).

    One dispatch group **per span position**: group s routes exactly the
    B tokens a stepwise ``decode_step`` at position s would route, so
    per-group capacity (``moe_serve_capacity(cfg, B)``; default B itself,
    i.e. drop-free) and in-group arrival ranking reproduce sequential
    absorption semantics — the fused path and the stepwise oracle make
    identical routing decisions by construction.  Continuation prefill
    (``transformer.prefill(..., continuation=True)``) reuses this dispatch
    unchanged: routing depends only on the hidden states and the valid
    mask, never on the absolute position values, so a span absorbed at
    offset positions over a live cache routes exactly as the same span
    inside a cold prefill of the concatenation (fully-masked trailing
    padding groups route to the sentinel segment and combine to zero).

    Padding tokens are masked three ways so padded and unpadded prompts
    dispatch identically: (1) router logits forced to -inf (no NaN:
    softmax of an all-masked row is uniform); (2) their assignments move
    to a sentinel expert segment (id E) which — ``argsort`` being stable —
    sorts after every real expert, so a padding token never consumes a
    capacity slot of a real expert in its group; (3) their combine weight
    is zeroed.  Capacity buffers keep their expert dim replicated (see
    ``moe_block``'s sharding note); the group dim is the sequence, which
    ``act_moe_group``/``act_expert_cap`` pin unsharded so the scatter
    stays a cheap 3-index per-group scatter under SPMD.
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    C = moe_serve_capacity(cfg, B)

    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    hf = h.swapaxes(0, 1)                                  # (S, B, D)
    valid = (positions >= 0).swapaxes(0, 1)                # (S, B)
    gates, idx, probs = _route_topk(p["router"], hf, cfg, valid=valid)

    A = B * k
    valid_a = jnp.repeat(valid, k, axis=1)                 # (S, A)
    flat_e = jnp.where(valid_a, idx.reshape(S, A), E)      # masked -> sentinel
    pos = _rank_in_expert(flat_e)
    keep = (pos < C) & valid_a
    e_idx = jnp.where(keep, flat_e, E - 1)
    c_idx = jnp.where(keep, pos, C)                        # dropped -> row C

    token_src = jnp.repeat(jnp.arange(B), k)               # (A,)
    src = jnp.take(hf, token_src, axis=1).astype(h.dtype)  # (S, A, D)
    gl = jnp.broadcast_to(jnp.arange(S)[:, None], e_idx.shape)
    buf = jnp.zeros((S, E, C + 1, D), src.dtype)
    buf = buf.at[gl, e_idx, c_idx].set(src, mode="drop")[:, :, :C]
    buf = constrain(buf, ("act_moe_group", None, "act_expert_cap", None),
                    mesh, rules)

    act = ACTIVATIONS[cfg.ffn_act]
    dt = h.dtype
    gate_h = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt))
    up_h = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    out_buf = jnp.einsum("gecf,efd->gecd", act(gate_h) * up_h,
                         p["w_down"].astype(dt))           # (S,E,C,D)
    out_buf = constrain(out_buf, ("act_moe_group", None, "act_expert_cap",
                                  None), mesh, rules)

    y = out_buf[gl, e_idx, jnp.minimum(c_idx, C - 1)]      # (S, A, D)
    w = (gates.reshape(S, A) * keep).astype(jnp.float32)
    y = (y.astype(jnp.float32) * w[..., None]).reshape(S, B, k, D).sum(2)
    if "shared" in p:
        y = y + _mlp_body(p["shared"], hf, cfg).astype(jnp.float32)
    y = y.swapaxes(0, 1)                                   # (B, S, D)

    # masked load-balance aux: padding excluded from both factors
    vf = valid.astype(jnp.float32)[..., None]              # (S, B, 1)
    cnt = jnp.maximum(vf.sum(), 1.0)
    top1 = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(((top1 * vf).sum((0, 1)) / cnt)
                       * ((probs * vf).sum((0, 1)) / cnt))
    return x + y.astype(x.dtype), aux


def _take_expert_rows(w, idx, dt):
    """Gather the k selected experts' weight rows.  Quantized weights
    (``quant.QTensor``) gather payload *and* scale rows and dequantize
    after the gather, so weight traffic stays k/E bytes as well as
    k/E FLOPs."""
    if isinstance(w, Q.QTensor):
        return w.take_rows(idx, dt)
    return jnp.take(w, idx, axis=0).astype(dt)


def moe_decode_block(p: dict, x: Array, cfg: ModelConfig, *,
                     mesh=None, rules=None) -> tuple[Array, Array]:
    """Constant-shape exact top-k dispatch for the decode step.

    x (B,1,D) — one token per sequence.  Default (``moe_decode_impl=
    "dispatch"``): reuse the per-position serving dispatch at S=1 — the
    buffer is one token column, shapes depend only on (B, k, C) so the
    decode-scan carry stays shape-stable, and because prefill uses the
    *same* dispatch einsums, fused prefill == stepwise absorption ==
    serve() bitwise through every MoE layer.  Drop-free by default
    (capacity = B), so serve()'s mixed-request slot batches — and the
    garbage its empty slots decode — can never perturb another slot's
    routing.

    ``moe_decode_impl="gather"`` instead gathers only the k selected
    experts' weight rows per token: expert FLOPs drop from E to k and
    weight traffic is 3·B·k·D·F_e (< the resident weights whenever
    B·k < E, the serving regime).  Numerically ~1 bf16 ulp off the
    dispatch einsums, so greedy parity with the stepwise oracle is no
    longer bit-guaranteed — an opt-in for large-E production decode
    (docs/RUNTIME.md).
    """
    B, S, D = x.shape
    if cfg.moe_decode_impl != "gather":
        return moe_prefill_block(p, x, cfg,
                                 jnp.zeros((B, S), jnp.int32),
                                 mesh=mesh, rules=rules)
    T = B * S                               # S == 1 on the decode path
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    hf = h.reshape(T, D)
    gates, idx, _ = _route_topk(p["router"], hf[None], cfg)
    gates, idx = gates[0], idx[0]                          # (T, k)

    dt = h.dtype
    wk = ("act_batch", "act_topk", None, "act_expert_ffn")
    wg = constrain(_take_expert_rows(p["w_gate"], idx, dt),
                   wk, mesh, rules)                        # (T,k,D,Fe)
    wu = constrain(_take_expert_rows(p["w_up"], idx, dt),
                   wk, mesh, rules)
    wd = constrain(_take_expert_rows(p["w_down"], idx, dt),
                   ("act_batch", "act_topk", "act_expert_ffn", None),
                   mesh, rules)                            # (T,k,Fe,D)

    act = ACTIVATIONS[cfg.ffn_act]
    gate_h = jnp.einsum("td,tkdf->tkf", hf, wg)
    up_h = jnp.einsum("td,tkdf->tkf", hf, wu)
    o = jnp.einsum("tkf,tkfd->tkd", act(gate_h) * up_h, wd)
    y = (o.astype(jnp.float32) * gates[..., None]).sum(1)  # (T, D)
    y = y.reshape(B, S, D)
    if "shared" in p:
        y = y + _mlp_body(p["shared"], h, cfg).astype(jnp.float32)
    return x + y.astype(x.dtype), jnp.zeros((), jnp.float32)
