"""Mixture-of-Experts FFN: capacity-based dispatch, EP-shardable.

Dispatch layout is *grouped*: tokens are reshaped into ``moe_groups`` groups
(one per data-parallel shard at the production mesh), each group dispatches
into its own (E, C_local) capacity buffer.  Scatter/gather indices then stay
aligned with the batch sharding, so SPMD keeps dispatch local to a (data,
model) shard pair — the only collectives are the ones real expert parallelism
needs (routed activations crossing the expert axis).

Supports DeepSeek-style shared experts (always-on dense branch) and top-k
renormalised softmax gating (top-1 == Switch, top-6 == DeepSeekMoE,
top-1+shared == Llama-4-Scout).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

try:                                    # jax >= 0.5
    from jax import shard_map as _shard_map
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.distributed.sharding import constrain
from repro.models.common import (ACTIVATIONS, ModelConfig, ParamDef, norm_def,
                                 normal_init, rmsnorm)
from repro.models.ffn import _mlp_body, mlp_defs

Array = jax.Array


def moe_defs(cfg: ModelConfig) -> dict:
    D, E, Fe = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    std_o = 0.02 / (2 * cfg.num_layers) ** 0.5
    defs = {
        "norm": norm_def(D),
        "router": ParamDef((D, E), ("embed", "experts"), normal_init()),
        "w_gate": ParamDef((E, D, Fe), ("experts", "embed", "expert_ffn"), normal_init()),
        "w_up": ParamDef((E, D, Fe), ("experts", "embed", "expert_ffn"), normal_init()),
        "w_down": ParamDef((E, Fe, D), ("experts", "expert_ffn", "embed"), normal_init(std_o)),
    }
    if cfg.num_shared_experts:
        shared = dict(mlp_defs(cfg, d_ff=cfg.num_shared_experts * cfg.expert_d_ff))
        shared.pop("norm")  # share the block norm
        defs["shared"] = shared
    return defs


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def moe_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(_round_up(c, 8), 8)


def moe_block(p: dict, x: Array, cfg: ModelConfig, *,
              groups: int = 1, mesh=None, rules=None) -> tuple[Array, Array]:
    """x (B,S,D) -> (x + moe(x), aux_loss).  groups must divide B*S.

    Sharding note: the capacity buffer is kept REPLICATED over the model
    axis (constrained below) so the dispatch scatter and combine gather stay
    local to each (data, model) shard — if the buffer's E dim is
    model-sharded, XLA SPMD rewrites the 3-index scatter into dense
    select-updates with (A, D)-sized u32 index tensors (measured 58 GB of
    u32 on deepseek train_4k; §Perf iteration 3).  The expert einsums then
    contract against model-sharded weights and their outputs are constrained
    back to replicated — one (g,E,C,D)-sized all-gather per layer instead.
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    T = B * S
    assert T % groups == 0, (T, groups)
    Tg = T // groups
    C = moe_capacity(cfg, Tg)

    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    hf = h.reshape(groups, Tg, D)

    # --- routing (f32) ---
    logits = jnp.einsum("gtd,de->gte", hf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (g,Tg,E)
    gates, idx = jax.lax.top_k(probs, k)                       # (g,Tg,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * mean_e(frac_tokens_e * mean_prob_e)
    onehot_top1 = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(jnp.mean(onehot_top1, axis=1) * jnp.mean(probs, axis=1))

    # --- dispatch: position of each assignment within its expert ---
    flat_e = idx.reshape(groups, Tg * k)                       # (g, A)
    A = Tg * k
    if cfg.moe_impl == "cumsum":
        # GShard-style one-hot cumsum: materialises (g, A, E) int32 —
        # measured 100+ GB/device at deepseek train_4k; kept for the
        # hillclimb before/after (EXPERIMENTS.md §Perf iteration 1)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (g, A, E)
        pos = jnp.cumsum(oh, axis=1) - oh
        pos = jnp.take_along_axis(
            pos, flat_e[..., None], axis=-1)[..., 0]           # (g, A)
    else:
        # sort-based ranking: O(A log A) and O(A) memory. argsort is
        # stable, so in-segment order == token order == cumsum semantics.
        # Segment starts come from a cummax over boundary markers (a vmapped
        # searchsorted segfaulted XLA:CPU under 512-way SPMD — see §Perf).
        sort_idx = jnp.argsort(flat_e, axis=1)                 # (g, A)
        sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
        ar = jnp.arange(A)[None, :]
        is_new = jnp.concatenate(
            [jnp.ones((groups, 1), bool),
             sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
        seg_start = jax.lax.cummax(jnp.where(is_new, ar, 0), axis=1)
        pos_sorted = ar - seg_start
        inv = jnp.argsort(sort_idx, axis=1)
        pos = jnp.take_along_axis(pos_sorted, inv, axis=1)     # (g, A)
    keep = pos < C
    # dropped assignments scatter to row C (then sliced off)
    e_idx = jnp.where(keep, flat_e, E - 1)
    c_idx = jnp.where(keep, pos, C)

    token_src = jnp.repeat(jnp.arange(Tg), k)                  # (A,)
    src = jnp.take(hf, token_src, axis=1).astype(h.dtype)      # (g, A, D)

    def _dispatch(src_l, e_l, c_l):
        gl = jnp.broadcast_to(jnp.arange(src_l.shape[0])[:, None], e_l.shape)
        b = jnp.zeros((src_l.shape[0], E, C + 1, D), src_l.dtype)
        return b.at[gl, e_l, c_l].set(src_l, mode="drop")[:, :, :C]

    def _combine(ob_l, e_l, c_l):
        gl = jnp.broadcast_to(jnp.arange(ob_l.shape[0])[:, None], e_l.shape)
        return ob_l[gl, e_l, jnp.minimum(c_l, C - 1)]

    # Dispatch/combine run under shard_map when the group dim divides the
    # batch axes: each (data, model) shard then executes a purely LOCAL
    # scatter/gather with (A,)-sized indices.  Left to SPMD propagation, the
    # 3-index scatter on an expert-sharded buffer gets rewritten into dense
    # select-updates with (A, D)-sized u32 index maps (measured 58 GB of u32
    # temps on deepseek train_4k; §Perf iterations 1-3).
    daxes = tuple(a for a in ("pod", "data") if mesh is not None
                  and a in mesh.shape)
    dp = 1
    for a in daxes:
        dp *= mesh.shape[a]
    use_smap = mesh is not None and daxes and groups % dp == 0
    if use_smap:
        from jax.sharding import PartitionSpec as P
        gspec = P(daxes if len(daxes) > 1 else daxes[0])
        smap = lambda f: _shard_map(f, mesh=mesh,
                                    in_specs=(gspec, gspec, gspec),
                                    out_specs=gspec)
        buf = smap(_dispatch)(src, e_idx, c_idx)
    else:
        buf = _dispatch(src, e_idx, c_idx)

    # --- expert compute (weights model-sharded over E) ---
    act = ACTIVATIONS[cfg.ffn_act]
    dt = h.dtype
    gate_h = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt))
    up_h = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    out_buf = jnp.einsum("gecf,efd->gecd", act(gate_h) * up_h,
                         p["w_down"].astype(dt))               # (g,E,C,D)
    out_buf = constrain(out_buf, ("act_batch", None, None, None), mesh, rules)

    # --- combine ---
    if use_smap:
        y = smap(_combine)(out_buf, e_idx, c_idx)              # (g,A,D)
    else:
        y = _combine(out_buf, e_idx, c_idx)
    w = (gates.reshape(groups, Tg * k) * keep).astype(jnp.float32)
    y = (y.astype(jnp.float32) * w[..., None]).reshape(groups, Tg, k, D).sum(2)

    if "shared" in p:
        y = y + _mlp_body(p["shared"], hf, cfg).astype(jnp.float32)

    return x + y.reshape(B, S, D).astype(x.dtype), aux
