"""Dense (gated) MLP block."""

from __future__ import annotations

import jax

from repro.models.common import (ACTIVATIONS, ModelConfig, ParamDef, norm_def,
                                 normal_init, rmsnorm)

Array = jax.Array


def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    std_o = 0.02 / (2 * cfg.num_layers) ** 0.5
    defs = {
        "norm": norm_def(D),
        "w_up": ParamDef((D, F), ("embed", "ffn"), normal_init()),
        "w_down": ParamDef((F, D), ("ffn", "embed"), normal_init(std_o)),
    }
    if cfg.ffn_act in ("swiglu", "geglu"):
        defs["w_gate"] = ParamDef((D, F), ("embed", "ffn"), normal_init())
    return defs


def mlp_block(p: dict, x: Array, cfg: ModelConfig) -> Array:
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    y = _mlp_body(p, h, cfg)
    return x + y


def _mlp_body(p: dict, h: Array, cfg: ModelConfig) -> Array:
    act = ACTIVATIONS[cfg.ffn_act]
    up = h @ p["w_up"].astype(h.dtype)
    if "w_gate" in p:
        up = act(h @ p["w_gate"].astype(h.dtype)) * up
    else:
        up = act(up)
    return up @ p["w_down"].astype(h.dtype)
