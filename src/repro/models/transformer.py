"""Model assembler: embeddings -> staged (scanned) blocks -> LM head.

One code path serves every assigned architecture: dense GQA decoders, MoE
(shared+routed), Mamba-2 SSD, RG-LRU hybrids, bidirectional encoders and the
stub-fronted VLM/audio variants.  Layers run as ``lax.scan`` over stacked
params (per ``ModelConfig.stage_plan``) so an 80-layer 110B model lowers to a
compact HLO for the multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.models import attention, ffn, moe, rglru, ssm
from repro.models.common import (ModelConfig, ParamDef, Stage, abstract_tree,
                                 axes_tree, init_tree, norm_def, normal_init,
                                 rmsnorm)

Array = jax.Array


# ---------------------------------------------------------------------------
# Param definitions
# ---------------------------------------------------------------------------

def block_defs(cfg: ModelConfig, kind: tuple[str, str]) -> dict:
    mixer, f = kind
    out: dict[str, Any] = {}
    if mixer in ("attn", "attn_local"):
        out["mixer"] = attention.attn_defs(cfg)
    elif mixer == "rglru":
        out["mixer"] = rglru.rglru_defs(cfg)
    elif mixer == "ssd":
        out["mixer"] = ssm.ssd_defs(cfg)
    else:
        raise ValueError(mixer)
    if f == "mlp":
        out["ffn"] = ffn.mlp_defs(cfg)
    elif f == "moe":
        out["ffn"] = moe.moe_defs(cfg)
    elif f != "none":
        raise ValueError(f)
    return out


def model_defs(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    defs: dict[str, Any] = {
        "embed": ParamDef((V, D), ("vocab", "embed"), normal_init()),
        "final_norm": norm_def(D),
    }
    stages = []
    for st in cfg.stage_plan():
        sdefs = {f"b{i}": block_defs(cfg, kind) for i, kind in enumerate(st.blocks)}
        if st.repeat > 1:
            sdefs = jax.tree.map(lambda d: d.with_leading(st.repeat), sdefs,
                                 is_leaf=lambda x: isinstance(x, ParamDef))
        stages.append(sdefs)
    defs["stages"] = stages
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((D, V), ("embed", "vocab"), normal_init())
    return defs


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    return init_tree(model_defs(cfg), key, cfg.dtype)


def abstract_params(cfg: ModelConfig) -> dict:
    return abstract_tree(model_defs(cfg), cfg.dtype)


def param_axes(cfg: ModelConfig) -> dict:
    return axes_tree(model_defs(cfg))


# ---------------------------------------------------------------------------
# Forward (full sequence: training / prefill)
# ---------------------------------------------------------------------------

def _apply_block(bp: dict, x: Array, cfg: ModelConfig, kind: tuple[str, str],
                 moe_groups: int, mesh, rules) -> tuple[Array, Array]:
    mixer, f = kind
    if mixer == "attn":
        x = attention.attn_block(bp["mixer"], x, cfg, local=False)
    elif mixer == "attn_local":
        x = attention.attn_block(bp["mixer"], x, cfg, local=True)
    elif mixer == "rglru":
        x = rglru.rglru_block(bp["mixer"], x, cfg)
    elif mixer == "ssd":
        x = ssm.ssd_block(bp["mixer"], x, cfg)
    aux = jnp.zeros((), jnp.float32)
    if f == "mlp":
        x = ffn.mlp_block(bp["ffn"], x, cfg)
    elif f == "moe":
        x, aux = moe.moe_block(bp["ffn"], x, cfg, groups=moe_groups,
                               mesh=mesh, rules=rules)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"), mesh, rules)
    return x, aux


def _run_stage(sp: dict, x: Array, cfg: ModelConfig, stage: Stage,
               moe_groups: int, mesh, rules) -> tuple[Array, Array]:
    def body_once(x, layer_params):
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(stage.blocks):
            x, a = _apply_block(layer_params[f"b{i}"], x, cfg, kind,
                                moe_groups, mesh, rules)
            aux = aux + a
        return x, aux

    if stage.repeat == 1:
        if cfg.remat:
            # match the scanned path's remat policy so unrolled slice models
            # (dry-run cost extrapolation) reproduce production recompute
            return jax.checkpoint(
                body_once,
                policy=jax.checkpoint_policies.nothing_saveable)(x, sp)
        return body_once(x, sp)

    def scan_body(carry, layer_params):
        x, aux = carry
        x, a = body_once(x, layer_params)
        return (x, aux + a), None

    if cfg.remat:
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)), sp)
    return x, aux


def embed_inputs(params: dict, cfg: ModelConfig, batch: dict,
                 mesh=None, rules=None) -> Array:
    """batch may contain `tokens` (B,S), and/or `frontend_embeds` (B,F,D)."""
    parts = []
    if "frontend_embeds" in batch:
        parts.append(batch["frontend_embeds"].astype(cfg.comp_dtype))
    if "tokens" in batch and batch["tokens"] is not None:
        tok = batch["tokens"]
        emb = jnp.take(params["embed"], tok, axis=0).astype(cfg.comp_dtype)
        parts.append(emb)
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return constrain(x, ("act_batch", "act_seq", "act_embed"), mesh, rules)


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            moe_groups: int = 1, mesh=None,
            rules: ShardingRules | None = None) -> tuple[Array, Array]:
    """Full-sequence forward -> (logits (B,S,V), moe_aux)."""
    x = embed_inputs(params, cfg, batch, mesh, rules)
    aux = jnp.zeros((), jnp.float32)
    for sp, stage in zip(params["stages"], cfg.stage_plan()):
        x, a = _run_stage(sp, x, cfg, stage, moe_groups, mesh, rules)
        aux = aux + a
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = constrain(logits, ("act_batch", "act_seq", "act_vocab"), mesh, rules)
    return logits, aux


def loss_fn(params: dict, cfg: ModelConfig, batch: dict, *,
            moe_groups: int = 1, mesh=None,
            rules: ShardingRules | None = None,
            aux_coef: float = 0.01) -> tuple[Array, dict]:
    """Next-token (or masked-unit, for encoders) cross entropy."""
    logits, aux = forward(params, cfg, batch, moe_groups=moe_groups,
                          mesh=mesh, rules=rules)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    # frontend tokens carry no labels; logits cover [frontend | text]
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    # fused iota-compare-select reduction instead of take_along_axis: a
    # gather on the vocab-sharded dim would force SPMD to all-gather the
    # full logits (measured: 52 GB/device on llama3-8b train_4k)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], lf, 0.0), axis=-1)
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom + aux_coef * aux
    metrics = {"nll": nll.sum() / denom, "moe_aux": aux,
               "tokens": mask.sum()}
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode (one token, cached state)
# ---------------------------------------------------------------------------

class LayerCache(NamedTuple):
    """Per-(stage, slot) cache. Exactly one field is used per mixer kind."""
    kv: Any = None
    rg: Any = None
    ssd: Any = None


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> list:
    """Cache pytree parallel to params['stages'] (stacked over scan repeats)."""
    caches = []
    for stage in cfg.stage_plan():
        sc = {}
        for i, (mixer, _) in enumerate(stage.blocks):
            if mixer in ("attn", "attn_local"):
                c = LayerCache(kv=attention.init_kv_cache(
                    cfg, batch, max_len, local=(mixer == "attn_local")))
            elif mixer == "rglru":
                c = LayerCache(rg=rglru.init_rglru_state(cfg, batch))
            elif mixer == "ssd":
                c = LayerCache(ssd=ssm.init_ssm_state(cfg, batch))
            if stage.repeat > 1:
                c = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (stage.repeat,) + a.shape), c)
            sc[f"b{i}"] = c
        caches.append(sc)
    return caches


def cache_axes(cfg: ModelConfig) -> list:
    """Logical-axis tree parallel to ``init_cache`` (for decode shardings)."""
    kv = attention.KVCache(
        k=("act_batch", "act_kv_seq", "act_kv_heads", None),
        v=("act_batch", "act_kv_seq", "act_kv_heads", None),
        pos=("act_batch", "act_kv_seq"))
    rg = rglru.RGLRUState(h=("act_batch", "act_ssm_inner"),
                          conv=("act_batch", None, "act_ssm_inner"))
    sd = ssm.SSMState(ssd=("act_batch", "act_heads", None, None),
                      conv=("act_batch", None, "act_ssm_inner"))
    out = []
    for stage in cfg.stage_plan():
        sc = {}
        for i, (mixer, _) in enumerate(stage.blocks):
            if mixer in ("attn", "attn_local"):
                c = LayerCache(kv=kv)
            elif mixer == "rglru":
                c = LayerCache(rg=rg)
            else:
                c = LayerCache(ssd=sd)
            if stage.repeat > 1:
                c = jax.tree.map(lambda a: (None,) + a, c,
                                 is_leaf=lambda x: isinstance(x, tuple) and
                                 all(isinstance(e, (str, type(None))) for e in x))
            sc[f"b{i}"] = c
        out.append(sc)
    return out


def _cached_block(bp: dict, x: Array, cache: LayerCache, posarg: Array,
                  cfg: ModelConfig, kind: tuple[str, str],
                  mesh=None, rules=None, *, is_prefill: bool,
                  continuation: bool = False) -> tuple[Array, LayerCache]:
    """One block with cache update — shared by prefill (posarg = positions
    (B,S)) and decode (posarg = index (B,)), so both paths always run the
    same block structure."""
    mixer, f = kind
    if mixer in ("attn", "attn_local"):
        if is_prefill:
            x, kv = attention.attn_prefill(
                bp["mixer"], x, cache.kv, posarg, cfg,
                local=(mixer == "attn_local"), continuation=continuation,
                mesh=mesh, rules=rules)
        else:
            x, kv = attention.attn_decode(
                bp["mixer"], x, cache.kv, posarg, cfg,
                local=(mixer == "attn_local"), mesh=mesh, rules=rules)
        cache = cache._replace(kv=kv)
    elif mixer == "rglru":
        if is_prefill:
            x, rg = rglru.rglru_prefill(bp["mixer"], x, cache.rg, posarg, cfg,
                                        mesh=mesh, rules=rules,
                                        continuation=continuation)
        else:
            x, rg = rglru.rglru_decode(bp["mixer"], x, cache.rg, cfg,
                                       mesh=mesh, rules=rules)
        cache = cache._replace(rg=rg)
    elif mixer == "ssd":
        if is_prefill:
            x, s = ssm.ssd_prefill(bp["mixer"], x, cache.ssd, posarg, cfg,
                                   mesh=mesh, rules=rules,
                                   continuation=continuation)
        else:
            x, s = ssm.ssd_decode(bp["mixer"], x, cache.ssd, cfg,
                                  mesh=mesh, rules=rules)
        cache = cache._replace(ssd=s)
    if f == "mlp":
        x = ffn.mlp_block(bp["ffn"], x, cfg)
    elif f == "moe":
        # serving-path MoE: per-position masked dispatch in prefill (posarg
        # is positions (B,S); negative = inert padding, excluded from the
        # per-group capacity counts), constant-shape exact top-k in decode —
        # both route exactly per-token, so fused == stepwise == serve.
        if is_prefill:
            x, _ = moe.moe_prefill_block(bp["ffn"], x, cfg, posarg,
                                         mesh=mesh, rules=rules)
        else:
            x, _ = moe.moe_decode_block(bp["ffn"], x, cfg,
                                        mesh=mesh, rules=rules)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"), mesh, rules)
    return x, cache


def constrain_cache(cache: list, cfg: ModelConfig, mesh=None,
                    rules=None) -> list:
    """Pin every cache leaf to its logical-axis sharding (no-op off-mesh).

    Applied right after ``init_cache`` inside a jitted prefill and at the
    exit of cache-splicing helpers, so the KV / recurrent state stays
    ``act_batch``-sharded (with ``act_kv_seq``/``act_kv_heads`` claiming the
    'model' axis where divisible) across the whole decode scan instead of
    being re-laid-out by whatever GSPMD infers step to step.
    """
    if mesh is None:
        return cache
    from jax.sharding import NamedSharding

    from repro.distributed.sharding import DEFAULT_RULES, spec_for
    rules = rules or DEFAULT_RULES
    axes = cache_axes(cfg)

    def one(leaf, ax):
        spec = spec_for(leaf.shape, ax, mesh, rules.act_rules)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))

    # cache leaves are arrays; flatten_up_to leaves the parallel logical-axis
    # tuples of ``cache_axes`` intact as the second argument
    return jax.tree.map(one, cache, axes)


def _cached_pass(params: dict, cfg: ModelConfig, tokens: Array, cache: list,
                 posarg: Array, is_prefill: bool,
                 mesh, rules, continuation: bool = False) -> tuple[Array, list]:
    """Embed -> staged cached blocks -> LM head, for prefill and decode."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.comp_dtype)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"), mesh, rules)
    new_caches = []
    for sp, stage, sc in zip(params["stages"], cfg.stage_plan(), cache):
        def stage_body(x, lp, lc, stage=stage):
            ncs = {}
            for i, kind in enumerate(stage.blocks):
                x, ncs[f"b{i}"] = _cached_block(
                    lp[f"b{i}"], x, lc[f"b{i}"], posarg, cfg, kind,
                    mesh, rules, is_prefill=is_prefill,
                    continuation=continuation)
            return x, ncs

        if stage.repeat == 1:
            x, nsc = stage_body(x, sp, sc)
        else:
            x, nsc = jax.lax.scan(
                lambda x, layer: stage_body(x, *layer), x, (sp, sc))
        new_caches.append(nsc)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = constrain(logits, ("act_batch", "act_seq", "act_vocab"), mesh, rules)
    return logits, new_caches


def prefill(params: dict, cfg: ModelConfig, tokens: Array, cache: list,
            positions: Array, *, continuation: bool = False, mesh=None,
            rules: ShardingRules | None = None) -> tuple[Array, list]:
    """Absorb a whole span in one pass, populating every layer cache.

    tokens (B,S) int32; positions (B,S) absolute positions (negative =>
    inert bucket padding, see the per-mixer prefill docstrings).  Returns
    (logits (B,S,V), cache) — the cache is ready for ``decode_step`` after
    the last real position.  Reuses the full-sequence mixers (chunked
    attention / associative scan / chunked SSD), so one jitted call replaces
    S sequential ``decode_step`` dispatches.

    ``continuation=False`` (cold): requires a FRESHLY INITIALISED cache and
    a LEFT-padded span starting at position 0; attention layers attend only
    over this span's K/V.

    ``continuation=True`` (warm): absorbs the span into an
    *already-populated* cache at offset positions.  The span must be
    RIGHT-padded (real tokens first) so the recurrent mixers' conv windows
    cross from the cached context tail straight into the new tokens;
    attention scatters the span K/V into the cache and attends over the
    whole cache.  Recurrent mixers fold the carried state into the scan in
    both modes — the flag only switches the attention read set and the
    conv-tail extraction.

    MoE layers run the capacity-aware masked serving dispatch
    (``moe.moe_prefill_block``) in both modes: one dispatch group per span
    position (offset positions included — routing depends only on the
    hidden states and the valid mask), padding tokens masked out of routing
    and capacity, so prefill makes the same routing decisions as S
    sequential ``decode_step`` calls and bucket padding is bitwise-neutral.
    """
    return _cached_pass(params, cfg, tokens, cache, positions, True,
                        mesh, rules, continuation=continuation)


def grow_cache(cfg: ModelConfig, cache: list, batch: int, new_len: int
               ) -> list:
    """Extend every KV-cache leaf to ``new_len`` slots (new slots empty:
    k/v zero, pos = -1).  Length-independent leaves (recurrent states, conv
    tails, window-clamped ring buffers that don't change size) pass through
    unchanged.  Used when a session outgrows the cache it was created with
    (multi-turn continuation, warm serve() admission into longer slots).
    ``new_len`` must be >= the current length."""
    tmpl = init_cache(cfg, batch, new_len)

    def one(t, c):
        if t.shape == c.shape:
            return c
        return jax.lax.dynamic_update_slice(t, c.astype(t.dtype),
                                            (0,) * c.ndim)

    return jax.tree.map(one, tmpl, cache)


def decode_step(params: dict, cfg: ModelConfig, tokens: Array, cache: list,
                index: Array, *, mesh=None,
                rules: ShardingRules | None = None
                ) -> tuple[Array, list]:
    """tokens (B,1) int32; index (B,) positions. -> (logits (B,1,V), cache).

    MoE layers use the constant-shape exact top-k dispatch
    (``moe.moe_decode_block``) — drop-free per-token routing, so batch
    composition (serve slots, garbage in empty slots) can never change
    another sequence's routing."""
    return _cached_pass(params, cfg, tokens, cache, index, False,
                        mesh, rules)
