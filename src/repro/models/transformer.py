"""Model assembler: embeddings -> staged (scanned) blocks -> LM head.

One code path serves every assigned architecture: dense GQA decoders, MoE
(shared+routed), Mamba-2 SSD, RG-LRU hybrids, bidirectional encoders and the
stub-fronted VLM/audio variants.  Layers run as ``lax.scan`` over stacked
params (per ``ModelConfig.stage_plan``) so an 80-layer 110B model lowers to a
compact HLO for the multi-pod dry-run.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, constrain
from repro.models import attention, ffn, moe, rglru, ssm
from repro.models.common import (ModelConfig, ParamDef, Stage, abstract_tree,
                                 axes_tree, init_tree, norm_def, normal_init,
                                 rmsnorm)

Array = jax.Array


# ---------------------------------------------------------------------------
# Param definitions
# ---------------------------------------------------------------------------

def block_defs(cfg: ModelConfig, kind: tuple[str, str]) -> dict:
    mixer, f = kind
    out: dict[str, Any] = {}
    if mixer in ("attn", "attn_local"):
        out["mixer"] = attention.attn_defs(cfg)
    elif mixer == "rglru":
        out["mixer"] = rglru.rglru_defs(cfg)
    elif mixer == "ssd":
        out["mixer"] = ssm.ssd_defs(cfg)
    else:
        raise ValueError(mixer)
    if f == "mlp":
        out["ffn"] = ffn.mlp_defs(cfg)
    elif f == "moe":
        out["ffn"] = moe.moe_defs(cfg)
    elif f != "none":
        raise ValueError(f)
    return out


def model_defs(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    defs: dict[str, Any] = {
        "embed": ParamDef((V, D), ("vocab", "embed"), normal_init()),
        "final_norm": norm_def(D),
    }
    stages = []
    for st in cfg.stage_plan():
        sdefs = {f"b{i}": block_defs(cfg, kind) for i, kind in enumerate(st.blocks)}
        if st.repeat > 1:
            sdefs = jax.tree.map(lambda d: d.with_leading(st.repeat), sdefs,
                                 is_leaf=lambda x: isinstance(x, ParamDef))
        stages.append(sdefs)
    defs["stages"] = stages
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((D, V), ("embed", "vocab"), normal_init())
    return defs


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    return init_tree(model_defs(cfg), key, cfg.dtype)


def abstract_params(cfg: ModelConfig) -> dict:
    return abstract_tree(model_defs(cfg), cfg.dtype)


def param_axes(cfg: ModelConfig) -> dict:
    return axes_tree(model_defs(cfg))


# ---------------------------------------------------------------------------
# Forward (full sequence: training / prefill)
# ---------------------------------------------------------------------------

def _apply_block(bp: dict, x: Array, cfg: ModelConfig, kind: tuple[str, str],
                 moe_groups: int, mesh, rules) -> tuple[Array, Array]:
    mixer, f = kind
    if mixer == "attn":
        x = attention.attn_block(bp["mixer"], x, cfg, local=False)
    elif mixer == "attn_local":
        x = attention.attn_block(bp["mixer"], x, cfg, local=True)
    elif mixer == "rglru":
        x = rglru.rglru_block(bp["mixer"], x, cfg)
    elif mixer == "ssd":
        x = ssm.ssd_block(bp["mixer"], x, cfg)
    aux = jnp.zeros((), jnp.float32)
    if f == "mlp":
        x = ffn.mlp_block(bp["ffn"], x, cfg)
    elif f == "moe":
        x, aux = moe.moe_block(bp["ffn"], x, cfg, groups=moe_groups,
                               mesh=mesh, rules=rules)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"), mesh, rules)
    return x, aux


def _run_stage(sp: dict, x: Array, cfg: ModelConfig, stage: Stage,
               moe_groups: int, mesh, rules) -> tuple[Array, Array]:
    def body_once(x, layer_params):
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(stage.blocks):
            x, a = _apply_block(layer_params[f"b{i}"], x, cfg, kind,
                                moe_groups, mesh, rules)
            aux = aux + a
        return x, aux

    if stage.repeat == 1:
        if cfg.remat:
            # match the scanned path's remat policy so unrolled slice models
            # (dry-run cost extrapolation) reproduce production recompute
            return jax.checkpoint(
                body_once,
                policy=jax.checkpoint_policies.nothing_saveable)(x, sp)
        return body_once(x, sp)

    def scan_body(carry, layer_params):
        x, aux = carry
        x, a = body_once(x, layer_params)
        return (x, aux + a), None

    if cfg.remat:
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)), sp)
    return x, aux


def embed_inputs(params: dict, cfg: ModelConfig, batch: dict,
                 mesh=None, rules=None) -> Array:
    """batch may contain `tokens` (B,S), and/or `frontend_embeds` (B,F,D)."""
    parts = []
    if "frontend_embeds" in batch:
        parts.append(batch["frontend_embeds"].astype(cfg.comp_dtype))
    if "tokens" in batch and batch["tokens"] is not None:
        tok = batch["tokens"]
        emb = jnp.take(params["embed"], tok, axis=0).astype(cfg.comp_dtype)
        parts.append(emb)
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return constrain(x, ("act_batch", "act_seq", "act_embed"), mesh, rules)


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            moe_groups: int = 1, mesh=None,
            rules: ShardingRules | None = None) -> tuple[Array, Array]:
    """Full-sequence forward -> (logits (B,S,V), moe_aux)."""
    x = embed_inputs(params, cfg, batch, mesh, rules)
    aux = jnp.zeros((), jnp.float32)
    for sp, stage in zip(params["stages"], cfg.stage_plan()):
        x, a = _run_stage(sp, x, cfg, stage, moe_groups, mesh, rules)
        aux = aux + a
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = constrain(logits, ("act_batch", "act_seq", "act_vocab"), mesh, rules)
    return logits, aux


def loss_fn(params: dict, cfg: ModelConfig, batch: dict, *,
            moe_groups: int = 1, mesh=None,
            rules: ShardingRules | None = None,
            aux_coef: float = 0.01) -> tuple[Array, dict]:
    """Next-token (or masked-unit, for encoders) cross entropy."""
    logits, aux = forward(params, cfg, batch, moe_groups=moe_groups,
                          mesh=mesh, rules=rules)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    # frontend tokens carry no labels; logits cover [frontend | text]
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    # fused iota-compare-select reduction instead of take_along_axis: a
    # gather on the vocab-sharded dim would force SPMD to all-gather the
    # full logits (measured: 52 GB/device on llama3-8b train_4k)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], lf, 0.0), axis=-1)
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom + aux_coef * aux
    metrics = {"nll": nll.sum() / denom, "moe_aux": aux,
               "tokens": mask.sum()}
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode (one token, cached state)
# ---------------------------------------------------------------------------

class LayerCache(NamedTuple):
    """Per-(stage, slot) cache. Exactly one field is used per mixer kind."""
    kv: Any = None
    rg: Any = None
    ssd: Any = None


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> list:
    """Cache pytree parallel to params['stages'] (stacked over scan repeats)."""
    caches = []
    for stage in cfg.stage_plan():
        sc = {}
        for i, (mixer, _) in enumerate(stage.blocks):
            if mixer in ("attn", "attn_local"):
                c = LayerCache(kv=attention.init_kv_cache(
                    cfg, batch, max_len, local=(mixer == "attn_local")))
            elif mixer == "rglru":
                c = LayerCache(rg=rglru.init_rglru_state(cfg, batch))
            elif mixer == "ssd":
                c = LayerCache(ssd=ssm.init_ssm_state(cfg, batch))
            if stage.repeat > 1:
                c = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (stage.repeat,) + a.shape), c)
            sc[f"b{i}"] = c
        caches.append(sc)
    return caches


def cache_axes(cfg: ModelConfig) -> list:
    """Logical-axis tree parallel to ``init_cache`` (for decode shardings)."""
    kv = attention.KVCache(
        k=("act_batch", "act_kv_seq", "act_kv_heads", None),
        v=("act_batch", "act_kv_seq", "act_kv_heads", None),
        pos=("act_batch", "act_kv_seq"))
    rg = rglru.RGLRUState(h=("act_batch", "act_ssm_inner"),
                          conv=("act_batch", None, "act_ssm_inner"))
    sd = ssm.SSMState(ssd=("act_batch", "act_heads", None, None),
                      conv=("act_batch", None, "act_ssm_inner"))
    out = []
    for stage in cfg.stage_plan():
        sc = {}
        for i, (mixer, _) in enumerate(stage.blocks):
            if mixer in ("attn", "attn_local"):
                c = LayerCache(kv=kv)
            elif mixer == "rglru":
                c = LayerCache(rg=rg)
            else:
                c = LayerCache(ssd=sd)
            if stage.repeat > 1:
                c = jax.tree.map(lambda a: (None,) + a, c,
                                 is_leaf=lambda x: isinstance(x, tuple) and
                                 all(isinstance(e, (str, type(None))) for e in x))
            sc[f"b{i}"] = c
        out.append(sc)
    return out


def _cached_block(bp: dict, x: Array, cache: LayerCache, posarg: Array,
                  cfg: ModelConfig, kind: tuple[str, str],
                  mesh=None, rules=None, *, is_prefill: bool,
                  continuation: bool = False) -> tuple[Array, LayerCache]:
    """One block with cache update — shared by prefill (posarg = positions
    (B,S)) and decode (posarg = index (B,)), so both paths always run the
    same block structure.  Paged caches never reach this level: the engine
    gathers their slot-linear view first (``paged_gather``) and runs this
    exact monolithic body on it, which is what makes paged serving bitwise-
    identical by construction."""
    mixer, f = kind
    if mixer in ("attn", "attn_local"):
        if is_prefill:
            x, kv = attention.attn_prefill(
                bp["mixer"], x, cache.kv, posarg, cfg,
                local=(mixer == "attn_local"), continuation=continuation,
                mesh=mesh, rules=rules)
        else:
            x, kv = attention.attn_decode(
                bp["mixer"], x, cache.kv, posarg, cfg,
                local=(mixer == "attn_local"), mesh=mesh, rules=rules)
        cache = cache._replace(kv=kv)
    elif mixer == "rglru":
        if is_prefill:
            x, rg = rglru.rglru_prefill(bp["mixer"], x, cache.rg, posarg, cfg,
                                        mesh=mesh, rules=rules,
                                        continuation=continuation)
        else:
            x, rg = rglru.rglru_decode(bp["mixer"], x, cache.rg, cfg,
                                       mesh=mesh, rules=rules)
        cache = cache._replace(rg=rg)
    elif mixer == "ssd":
        if is_prefill:
            x, s = ssm.ssd_prefill(bp["mixer"], x, cache.ssd, posarg, cfg,
                                   mesh=mesh, rules=rules,
                                   continuation=continuation)
        else:
            x, s = ssm.ssd_decode(bp["mixer"], x, cache.ssd, cfg,
                                  mesh=mesh, rules=rules)
        cache = cache._replace(ssd=s)
    if f == "mlp":
        x = ffn.mlp_block(bp["ffn"], x, cfg)
    elif f == "moe":
        # serving-path MoE: per-position masked dispatch in prefill (posarg
        # is positions (B,S); negative = inert padding, excluded from the
        # per-group capacity counts), constant-shape exact top-k in decode —
        # both route exactly per-token, so fused == stepwise == serve.
        if is_prefill:
            x, _ = moe.moe_prefill_block(bp["ffn"], x, cfg, posarg,
                                         mesh=mesh, rules=rules)
        else:
            x, _ = moe.moe_decode_block(bp["ffn"], x, cfg,
                                        mesh=mesh, rules=rules)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"), mesh, rules)
    return x, cache


def constrain_cache(cache: list, cfg: ModelConfig, mesh=None,
                    rules=None) -> list:
    """Pin every cache leaf to its logical-axis sharding (no-op off-mesh).

    Applied right after ``init_cache`` inside a jitted prefill and at the
    exit of cache-splicing helpers, so the KV / recurrent state stays
    ``act_batch``-sharded (with ``act_kv_seq``/``act_kv_heads`` claiming the
    'model' axis where divisible) across the whole decode scan instead of
    being re-laid-out by whatever GSPMD infers step to step.
    """
    if mesh is None:
        return cache
    from jax.sharding import NamedSharding

    from repro.distributed.sharding import DEFAULT_RULES, spec_for
    rules = rules or DEFAULT_RULES
    axes = (paged_cache_axes(cfg, quantized=cache_is_quantized(cache))
            if is_paged(cache) else cache_axes(cfg))

    def one(leaf, ax):
        spec = spec_for(leaf.shape, ax, mesh, rules.act_rules)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))

    # cache leaves are arrays; flatten_up_to leaves the parallel logical-axis
    # tuples of ``cache_axes`` intact as the second argument
    return jax.tree.map(one, cache, axes)


def is_paged(cache) -> bool:
    """True for the paged cache pytree ``{"layers", "table", "rows"}``."""
    return isinstance(cache, dict)


def cache_is_quantized(cache) -> bool:
    """True when a paged cache's KV pools carry scale sidecar leaves.

    Structural (``is not None``), so it is trace-safe: quantization is part
    of the pytree structure, never a runtime value."""
    for sc in cache["layers"]:
        for c in sc.values():
            if c.kv is not None:
                return c.kv.k_scale is not None
    return False


def paged_cache(layers: list, table: Array, rows: Array) -> dict:
    """Assemble the paged cache pytree the serving phases thread through
    jit: the engine-wide pool arrays + this dispatch's block tables and
    state-row ids (see serving/cache_manager.py)."""
    return {"layers": layers, "table": table, "rows": rows}


def _cached_pass(params: dict, cfg: ModelConfig, tokens: Array, cache: list,
                 posarg: Array, is_prefill: bool,
                 mesh, rules, continuation: bool = False) -> tuple[Array, list]:
    """Embed -> staged cached blocks -> LM head, for prefill and decode."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.comp_dtype)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"), mesh, rules)
    new_caches = []
    for sp, stage, sc in zip(params["stages"], cfg.stage_plan(), cache):
        def stage_body(x, lp, lc, stage=stage):
            ncs = {}
            for i, kind in enumerate(stage.blocks):
                x, ncs[f"b{i}"] = _cached_block(
                    lp[f"b{i}"], x, lc[f"b{i}"], posarg, cfg, kind,
                    mesh, rules, is_prefill=is_prefill,
                    continuation=continuation)
            return x, ncs

        if stage.repeat == 1:
            x, nsc = stage_body(x, sp, sc)
        else:
            x, nsc = jax.lax.scan(
                lambda x, layer: stage_body(x, *layer), x, (sp, sc))
        new_caches.append(nsc)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = constrain(logits, ("act_batch", "act_seq", "act_vocab"), mesh, rules)
    return logits, new_caches


def prefill(params: dict, cfg: ModelConfig, tokens: Array, cache: list,
            positions: Array, *, continuation: bool = False, mesh=None,
            rules: ShardingRules | None = None) -> tuple[Array, list]:
    """Absorb a whole span in one pass, populating every layer cache.

    tokens (B,S) int32; positions (B,S) absolute positions (negative =>
    inert bucket padding, see the per-mixer prefill docstrings).  Returns
    (logits (B,S,V), cache) — the cache is ready for ``decode_step`` after
    the last real position.  Reuses the full-sequence mixers (chunked
    attention / associative scan / chunked SSD), so one jitted call replaces
    S sequential ``decode_step`` dispatches.

    ``continuation=False`` (cold): requires a FRESHLY INITIALISED cache and
    a LEFT-padded span starting at position 0; attention layers attend only
    over this span's K/V.

    ``continuation=True`` (warm): absorbs the span into an
    *already-populated* cache at offset positions.  The span must be
    RIGHT-padded (real tokens first) so the recurrent mixers' conv windows
    cross from the cached context tail straight into the new tokens;
    attention scatters the span K/V into the cache and attends over the
    whole cache.  Recurrent mixers fold the carried state into the scan in
    both modes — the flag only switches the attention read set and the
    conv-tail extraction.

    MoE layers run the capacity-aware masked serving dispatch
    (``moe.moe_prefill_block``) in both modes: one dispatch group per span
    position (offset positions included — routing depends only on the
    hidden states and the valid mask), padding tokens masked out of routing
    and capacity, so prefill makes the same routing decisions as S
    sequential ``decode_step`` calls and bucket padding is bitwise-neutral.
    """
    return _cached_pass(params, cfg, tokens, cache, positions, True,
                        mesh, rules, continuation=continuation)


def grow_cache(cfg: ModelConfig, cache: list, batch: int, new_len: int
               ) -> list:
    """Extend every KV-cache leaf to ``new_len`` slots (new slots empty:
    k/v zero, pos = -1).  Length-independent leaves (recurrent states, conv
    tails, window-clamped ring buffers that don't change size) pass through
    unchanged.  Used when a session outgrows the cache it was created with
    (multi-turn continuation, warm serve() admission into longer slots).
    ``new_len`` must be >= the current length."""
    tmpl = init_cache(cfg, batch, new_len)

    def one(t, c):
        if t.shape == c.shape:
            return c
        return jax.lax.dynamic_update_slice(t, c.astype(t.dtype),
                                            (0,) * c.ndim)

    return jax.tree.map(one, tmpl, cache)


# ---------------------------------------------------------------------------
# Paged block pool (serving/cache_manager.py owns the allocator)
# ---------------------------------------------------------------------------

def init_block_pool(cfg: ModelConfig, n_blocks: int, block_len: int,
                    n_rows: int, cache_quant: str | None = None) -> list:
    """Pool arrays for the paged cache, structure parallel to
    ``init_cache``: attention layers hold ``(n_blocks, block_len, ...)`` KV
    blocks, recurrent/conv layers hold ``(n_rows, ...)`` state rows (the
    same leaves as a batch-``n_rows`` monolithic state — rows are just
    pooled batch slots addressed by id).  ``cache_quant`` stores the KV
    blocks int8/fp8 with per-row f32 scale leaves riding alongside;
    recurrent/conv state rows ALWAYS stay bf16 — compounding recurrences
    drift under requantization (the same reason their f32 accumulator
    sites carry dtype-drift pragmas)."""
    pools = []
    for stage in cfg.stage_plan():
        sc = {}
        for i, (mixer, _) in enumerate(stage.blocks):
            if mixer in ("attn", "attn_local"):
                c = LayerCache(kv=attention.init_paged_kv(
                    cfg, n_blocks, block_len, cache_quant))
            elif mixer == "rglru":
                c = LayerCache(rg=rglru.init_rglru_state(cfg, n_rows))
            elif mixer == "ssd":
                c = LayerCache(ssd=ssm.init_ssm_state(cfg, n_rows))
            if stage.repeat > 1:
                c = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (stage.repeat,) + a.shape), c)
            sc[f"b{i}"] = c
        pools.append(sc)
    return pools


def paged_cache_axes(cfg: ModelConfig, quantized: bool = False) -> dict:
    """Logical-axis tree parallel to ``paged_cache(init_block_pool(...))``:
    the pool block/row dim shards over 'data' (``act_pool`` rule), block
    tables and row ids ride with the batch.  ``quantized`` adds the scale
    sidecar leaves (``act_pool_scale`` rule — same 'data' chain over the
    block dim) so the axes tree stays structurally parallel to a
    ``cache_quant`` pool."""
    scale = attention.PAGED_SCALE_AXES if quantized else None
    kv = attention.KVCache(k=attention.PAGED_KV_AXES,
                           v=attention.PAGED_KV_AXES,
                           pos=("act_pool", None),
                           k_scale=scale, v_scale=scale)
    rg = rglru.RGLRUState(h=("act_pool", "act_ssm_inner"),
                          conv=("act_pool", None, "act_ssm_inner"))
    sd = ssm.SSMState(ssd=("act_pool", "act_heads", None, None),
                      conv=("act_pool", None, "act_ssm_inner"))
    out = []
    for stage in cfg.stage_plan():
        sc = {}
        for i, (mixer, _) in enumerate(stage.blocks):
            if mixer in ("attn", "attn_local"):
                c = LayerCache(kv=kv)
            elif mixer == "rglru":
                c = LayerCache(rg=rg)
            else:
                c = LayerCache(ssd=sd)
            if stage.repeat > 1:
                c = jax.tree.map(lambda a: (None,) + a, c,
                                 is_leaf=lambda x: isinstance(x, tuple) and
                                 all(isinstance(e, (str, type(None))) for e in x))
            sc[f"b{i}"] = c
        out.append(sc)
    return {"layers": out, "table": ("act_batch", None),
            "rows": ("act_batch",)}


def _local_nb(cfg: ModelConfig, nb: int, block_len: int, mixer: str) -> int:
    """Blocks a layer's slot-linear view spans: the full table, clamped to
    the window for local-attention layers (mirrors ``init_kv_cache``'s
    ring-buffer clamp; the engine validates window % block_len == 0)."""
    if mixer == "attn_local" and cfg.window is not None:
        return min(nb, max(cfg.window // block_len, 1))
    return nb


def paged_gather(cfg: ModelConfig, cache: dict) -> list:
    """Materialise the slot-linear **monolithic** view of a paged cache.

    Per attention layer: gather the table's pool blocks into a
    (B, nb*L, ...) ``KVCache`` (window-clamped for local layers); per
    recurrent layer: gather the slot's state rows.  With the same writes
    applied, the result is elementwise-equal to the cache ``init_cache``
    would have produced — the engine runs the UNCHANGED monolithic
    prefill/decode bodies on it, which is what makes the paged runtime
    bitwise-identical by construction.  O(B * table length) per dispatch,
    and the pool stays OUT of the decode-scan carry (carrying the pool
    would cost O(pool) per step — measured 10x on the smoke decode)."""
    layers, table, rows = cache["layers"], cache["table"], cache["rows"]
    nb = table.shape[1]
    out = []
    for stage, sc in zip(cfg.stage_plan(), layers):
        ns = {}
        for i, (mixer, _) in enumerate(stage.blocks):
            c = sc[f"b{i}"]
            stacked = stage.repeat > 1
            if c.kv is not None:
                L = c.kv.k.shape[2 if stacked else 1]
                tbl = table[:, :_local_nb(cfg, nb, L, mixer)]

                def pv(kv, tb):
                    # quantized pools dequantize inside paged_view, so the
                    # gathered view is ALWAYS a plain cfg-dtype monolithic
                    # cache and the compute bodies below never see scales
                    return attention.paged_view(kv, tb, cfg.dtype)
                view = (jax.vmap(pv, in_axes=(0, None))(c.kv, tbl)
                        if stacked else pv(c.kv, tbl))
                c = LayerCache(kv=view)
            else:
                axis = 1 if stacked else 0
                c = jax.tree.map(
                    lambda a: jnp.take(a, rows, axis=axis, mode="clip"), c)
            ns[f"b{i}"] = c
        out.append(ns)
    return out


def paged_scatter_back(cfg: ModelConfig, cache: dict, lin: list,
                       lo: Array, hi: Array) -> list:
    """Write a dispatch's results back into the pool: the blocks covering
    the written position range [lo, hi) per row (``attention.
    paged_scatter_blocks`` — O(tokens written), shared prefix blocks are
    never touched) plus the slot's recurrent state rows.  Sentinel table /
    row ids (empty serve slots) drop their writes."""
    layers, table, rows = cache["layers"], cache["table"], cache["rows"]
    nb = table.shape[1]
    out = []
    for stage, sc, sl in zip(cfg.stage_plan(), layers, lin):
        ns = {}
        for i, (mixer, _) in enumerate(stage.blocks):
            c, l = sc[f"b{i}"], sl[f"b{i}"]
            stacked = stage.repeat > 1
            if c.kv is not None:
                L = c.kv.k.shape[2 if stacked else 1]
                tbl = table[:, :_local_nb(cfg, nb, L, mixer)]
                win = cfg.window if mixer == "attn_local" else None
                scat = lambda p, v: attention.paged_scatter_blocks(
                    p, tbl, v, lo, hi, window=win)
                kv = (jax.vmap(scat)(c.kv, l.kv) if stacked
                      else scat(c.kv, l.kv))
                c = c._replace(kv=kv)
            else:
                axis = 1 if stacked else 0

                def one(pool_leaf, lin_leaf, axis=axis):
                    idx = (slice(None), rows) if axis else rows
                    return pool_leaf.at[idx].set(
                        lin_leaf.astype(pool_leaf.dtype), mode="drop")
                c = jax.tree.map(one, c, l)
            ns[f"b{i}"] = c
        out.append(ns)
    return out


def _map_kv_pools(cfg: ModelConfig, layers: list, fn) -> list:
    """Apply ``fn(kv_pool, stacked)`` to every attention pool leaf group."""
    out = []
    for stage, sc in zip(cfg.stage_plan(), layers):
        ns = {}
        for name, c in sc.items():
            ns[name] = (c._replace(kv=fn(c.kv, stage.repeat > 1))
                        if c.kv is not None else c)
        out.append(ns)
    return out


def _map_state_pools(cfg: ModelConfig, layers: list, fn) -> list:
    """Apply ``fn(state_leaf, stacked)`` to every recurrent state leaf."""
    out = []
    for stage, sc in zip(cfg.stage_plan(), layers):
        ns = {}
        for name, c in sc.items():
            stacked = stage.repeat > 1
            if c.rg is not None:
                c = c._replace(rg=jax.tree.map(
                    lambda a: fn(a, stacked), c.rg))
            elif c.ssd is not None:
                c = c._replace(ssd=jax.tree.map(
                    lambda a: fn(a, stacked), c.ssd))
            ns[name] = c
        out.append(ns)
    return out


def reset_blocks(cfg: ModelConfig, layers: list, ids: Array) -> list:
    """Re-initialise pool blocks ``ids`` (n,) in every KV pool: k/v zeroed,
    pos = -1 (quantized pools also zero the blocks' scale rows — exactly
    what quantizing a zero row scatters, see ``quant.quantize_rows``).
    O(len(ids)) — this replaces ``grow_cache``'s whole-buffer copy for
    paged session growth.  State rows are untouched."""
    def one(kv, stacked):
        # leaf -> same leaf with blocks ``ids`` set to ``val``; every KV
        # pool leaf (k/v/pos/scales) has the block dim first (or second
        # when repeat-stacked)
        def z(a, val):
            return a.at[:, ids].set(val) if stacked else a.at[ids].set(val)
        kv = kv._replace(k=z(kv.k, 0), v=z(kv.v, 0), pos=z(kv.pos, -1))
        if kv.k_scale is not None:
            kv = kv._replace(k_scale=z(kv.k_scale, 0),
                             v_scale=z(kv.v_scale, 0))
        return kv
    return _map_kv_pools(cfg, layers, one)


def copy_blocks(cfg: ModelConfig, layers: list, src: Array,
                dst: Array) -> list:
    """Copy pool blocks ``src`` -> ``dst`` in every KV pool (the COW copy:
    O(blocks copied), at most the one partially filled tail block per
    diverging slot).  Scale sidecar leaves copy with their blocks — COW
    and prefix sharing never requantize."""
    def one(kv, stacked):
        def cp(a):
            return (a.at[:, dst].set(a[:, src]) if stacked
                    else a.at[dst].set(a[src]))
        kv = kv._replace(k=cp(kv.k), v=cp(kv.v), pos=cp(kv.pos))
        if kv.k_scale is not None:
            kv = kv._replace(k_scale=cp(kv.k_scale), v_scale=cp(kv.v_scale))
        return kv
    return _map_kv_pools(cfg, layers, one)


def reset_rows(cfg: ModelConfig, layers: list, ids: Array) -> list:
    """Zero recurrent/conv state rows ``ids`` in every state pool (a fresh
    row must equal the monolithic ``init_cache`` zero state bitwise)."""
    def one(leaf, stacked):
        return leaf.at[:, ids].set(0) if stacked else leaf.at[ids].set(0)
    return _map_state_pools(cfg, layers, one)


def copy_rows(cfg: ModelConfig, layers: list, src: Array, dst: Array) -> list:
    """Copy state rows ``src`` -> ``dst`` (state rows are rewritten every
    decode step, so forking a session copies them instead of sharing)."""
    def one(leaf, stacked):
        if stacked:
            return leaf.at[:, dst].set(leaf[:, src])
        return leaf.at[dst].set(leaf[src])
    return _map_state_pools(cfg, layers, one)


# ---------------------------------------------------------------------------
# Kernel-first paged decode: attention reads pool blocks in place
# ---------------------------------------------------------------------------

def paged_decode_carry(cfg: ModelConfig, cache: dict, steps: int) -> list:
    """Initial carry for the kernel-first decode scan: per attention layer an
    O(B * steps) delta write buffer (``attention.init_decode_delta``), per
    recurrent layer the O(B) gathered state rows.  Unlike the gathered-view
    path there is NO cache-length state in the carry — the KV pool itself is
    a closed-over scan constant that ``paged_decode_step`` reads in place."""
    layers, rows = cache["layers"], cache["rows"]
    B = cache["table"].shape[0]
    out = []
    for stage, sc in zip(cfg.stage_plan(), layers):
        ns = {}
        for i, (mixer, _) in enumerate(stage.blocks):
            c = sc[f"b{i}"]
            stacked = stage.repeat > 1
            if c.kv is not None:
                d = attention.init_decode_delta(cfg, B, steps)
                if stacked:
                    d = jax.tree.map(lambda a: jnp.broadcast_to(
                        a, (stage.repeat,) + a.shape), d)
                c = LayerCache(kv=d)
            else:
                axis = 1 if stacked else 0
                c = jax.tree.map(
                    lambda a: jnp.take(a, rows, axis=axis, mode="clip"), c)
            ns[f"b{i}"] = c
        out.append(ns)
    return out


def _paged_block_step(bp: dict, x: Array, pool_c: LayerCache,
                      delta_c: LayerCache, table: Array, index: Array,
                      t: Array, p0: Array, cfg: ModelConfig,
                      kind: tuple[str, str], mesh, rules, layer=None
                      ) -> tuple[Array, LayerCache]:
    """One kernel-first decode block: attention attends through the block
    table in place (pool never copied), recurrent mixers run the unchanged
    monolithic decode on their carried state rows.  In a stacked stage
    ``pool_c`` holds the whole repeat-stacked pool and ``layer`` the stage
    scan's layer index — attention folds it into its block gathers, so the
    stage scan never slices (copies) a per-layer pool."""
    mixer, f = kind
    if mixer in ("attn", "attn_local"):
        L = pool_c.kv.k.shape[2 if layer is not None else 1]
        tbl = table[:, :_local_nb(cfg, table.shape[1], L, mixer)]
        x, d = attention.attn_decode_paged(
            bp["mixer"], x, pool_c.kv, tbl, delta_c.kv, index, t, p0, cfg,
            local=(mixer == "attn_local"), layer=layer, mesh=mesh,
            rules=rules)
        delta_c = delta_c._replace(kv=d)
    elif mixer == "rglru":
        x, rg = rglru.rglru_decode(bp["mixer"], x, delta_c.rg, cfg,
                                   mesh=mesh, rules=rules)
        delta_c = delta_c._replace(rg=rg)
    elif mixer == "ssd":
        x, s = ssm.ssd_decode(bp["mixer"], x, delta_c.ssd, cfg,
                              mesh=mesh, rules=rules)
        delta_c = delta_c._replace(ssd=s)
    if f == "mlp":
        x = ffn.mlp_block(bp["ffn"], x, cfg)
    elif f == "moe":
        x, _ = moe.moe_decode_block(bp["ffn"], x, cfg, mesh=mesh, rules=rules)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"), mesh, rules)
    return x, delta_c


def paged_decode_step(params: dict, cfg: ModelConfig, tokens: Array,
                      cache: dict, delta: list, index: Array, t: Array,
                      p0: Array, *, mesh=None,
                      rules: ShardingRules | None = None
                      ) -> tuple[Array, list]:
    """Kernel-first ``decode_step``: tokens (B,1), index (B,) -> (logits,
    delta).  ``cache`` is the paged pool pytree, closed over as a scan
    CONSTANT — attention reads KV blocks in place through the block table
    and never materialises the slot-linear view; ``delta``
    (``paged_decode_carry``) collects the dispatch's writes; ``t`` is the
    step number within the dispatch, ``p0`` the dispatch-start index.
    Stacked stages run as a lax.scan over (params, delta, layer-index) —
    the SAME stage structure as ``_cached_pass``, which matters for bitwise
    parity: XLA fuses a scan body differently from a Python unroll
    (measured 1-ulp logit noise on the smoke config), so the kernel-first
    path presents the shared block ops inside an identical scan body.  The
    pool is NOT scan xs: slicing a per-layer pool per repeat would copy the
    whole pool every decode step, so the stacked pool stays closed over and
    attention folds the layer index into its block gathers
    (``attn_decode_paged(layer=...)``) — the outer decode scan carries no
    O(pool) state and the stage scan moves none."""
    layers, table = cache["layers"], cache["table"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.comp_dtype)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"), mesh, rules)
    new_delta = []
    for sp, stage, sc, dc in zip(params["stages"], cfg.stage_plan(), layers,
                                 delta):
        def stage_body(x, lp, d_c, li, stage=stage, sc=sc):
            nds = {}
            for i, kind in enumerate(stage.blocks):
                x, nds[f"b{i}"] = _paged_block_step(
                    lp[f"b{i}"], x, sc[f"b{i}"], d_c[f"b{i}"], table,
                    index, t, p0, cfg, kind, mesh, rules, layer=li)
            return x, nds

        if stage.repeat == 1:
            x, ns = stage_body(x, sp, dc, None)
        else:
            x, ns = jax.lax.scan(
                lambda x, xs_l: stage_body(x, xs_l[0], xs_l[1], xs_l[2]),
                x, (sp, dc, jnp.arange(stage.repeat, dtype=jnp.int32)))
        new_delta.append(ns)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = constrain(logits, ("act_batch", "act_seq", "act_vocab"), mesh,
                       rules)
    return logits, new_delta


def paged_scatter_decode(cfg: ModelConfig, cache: dict, delta: list,
                         p0: Array) -> list:
    """End-of-dispatch writeback for the kernel-first decode: scatter each
    attention layer's delta rows into their pool slots through the table
    (``attention.paged_scatter_delta`` — O(steps) writes per row) and each
    recurrent layer's carried state rows.  Produces pools elementwise-equal
    to the gathered path's ``paged_scatter_back``; sentinel table entries /
    row ids drop."""
    layers, table, rows = cache["layers"], cache["table"], cache["rows"]
    nb = table.shape[1]
    out = []
    for stage, sc, dl in zip(cfg.stage_plan(), layers, delta):
        ns = {}
        for i, (mixer, _) in enumerate(stage.blocks):
            c, d = sc[f"b{i}"], dl[f"b{i}"]
            stacked = stage.repeat > 1
            if c.kv is not None:
                L = c.kv.k.shape[2 if stacked else 1]
                tbl = table[:, :_local_nb(cfg, nb, L, mixer)]
                win = cfg.window if mixer == "attn_local" else None
                scat = lambda p, v: attention.paged_scatter_delta(
                    p, tbl, v, p0, window=win)
                kv = (jax.vmap(scat)(c.kv, d.kv) if stacked
                      else scat(c.kv, d.kv))
                c = c._replace(kv=kv)
            else:
                axis = 1 if stacked else 0

                def one(pool_leaf, d_leaf, axis=axis):
                    idx = (slice(None), rows) if axis else rows
                    return pool_leaf.at[idx].set(
                        d_leaf.astype(pool_leaf.dtype), mode="drop")
                c = jax.tree.map(one, c, d)
            ns[f"b{i}"] = c
        out.append(ns)
    return out


def decode_step(params: dict, cfg: ModelConfig, tokens: Array, cache: list,
                index: Array, *, mesh=None,
                rules: ShardingRules | None = None
                ) -> tuple[Array, list]:
    """tokens (B,1) int32; index (B,) positions. -> (logits (B,1,V), cache).

    MoE layers use the constant-shape exact top-k dispatch
    (``moe.moe_decode_block``) — drop-free per-token routing, so batch
    composition (serve slots, garbage in empty slots) can never change
    another sequence's routing."""
    return _cached_pass(params, cfg, tokens, cache, index, False,
                        mesh, rules)
