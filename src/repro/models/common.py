"""Shared model substrate: config, param definitions, norms, RoPE, init.

Pure JAX (no flax): parameters are nested dicts of arrays.  Every model
module exposes three parallel builders:

  * ``*_defs(cfg)``   -> dict[name, ParamDef]  (shape, logical axes, init)
  * materialise with ``init_tree`` (real arrays) or ``abstract_tree``
    (ShapeDtypeStruct — used by the multi-pod dry-run so that a 110B model
    never allocates host memory).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encoder | vlm | audio

    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 32
    d_ff: int = 256
    vocab_size: int = 256

    # attention
    attn_bias: bool = False            # qwen-style QKV bias
    rope_theta: float = 10_000.0
    causal: bool = True                # False => bidirectional encoder
    window: int | None = None          # sliding-window size for "attn_local"
    mixer_pattern: tuple[str, ...] = ("attn",)   # cycled per layer

    # ffn
    ffn_act: str = "swiglu"            # swiglu | geglu | gelu

    # moe
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    first_k_dense: int = 0             # deepseek: leading dense layers
    moe_every: int = 1                 # moe on layers where (i % moe_every)==moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # serving-path (prefill) dispatch capacity: None = per-group capacity ==
    # group size, i.e. drop-free exact top-k (fused generate bitwise-matches
    # stepwise absorption); a float bounds it like the training dispatch
    # (capacity = tokens*k/E*factor, overflow drops) — smaller buffers at
    # large serve batches, no exactness guarantee.  See moe.moe_serve_capacity.
    moe_serve_capacity_factor: float | None = None
    # decode-step MoE impl: "dispatch" shares the prefill dispatch einsums
    # (bitwise fused/stepwise/serve parity); "gather" pulls only the top-k
    # experts' weight rows per token (k/E of the FLOPs, ~1 ulp noise).
    moe_decode_impl: str = "dispatch"

    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 128

    # rg-lru (griffin / recurrentgemma)
    rnn_width: int = 0
    rnn_conv_width: int = 4

    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    frontend: str | None = None        # None | "audio_frames" | "vision_patches"
    frontend_tokens: int = 0           # tokens contributed by the stub frontend
    dtype: Any = jnp.bfloat16          # parameter / KV-cache storage dtype
    compute_dtype: Any = None          # matmul operand dtype (None = dtype);
    # f8 storage + bf16 compute is the quantised-serving variant (the paper
    # itself serves 4-bit SLMs at the edge — §Perf iteration log)
    remat: bool = True
    scan_layers: bool = True
    attn_q_block: int = 512            # chunked-attention block sizes
    attn_kv_block: int = 1024
    # decode-attention KV chunk: the streaming-softmax chunk length for the
    # one-token decode attend.  All decode layouts (monolithic, gathered
    # paged view, kernel-first block-table) stream the SAME chunk math, so
    # they stay bitwise-identical; only chunk provenance differs.  Halved
    # statically until it divides the cache length (windows can be < 64).
    attn_decode_block: int = 64
    # prefill attention impl: "chunked" = the XLA two-level-scan online
    # softmax below; "flash" = kernels/flash_attention (Pallas, interpret
    # off-TPU); None = per-backend default (flash on TPU, chunked on CPU).
    attn_prefill_impl: str | None = None
    moe_impl: str = "sort"             # sort | cumsum (see §Perf hillclimb)

    # ---- derived -----------------------------------------------------
    @property
    def comp_dtype(self):
        return self.compute_dtype or self.dtype

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def layer_plan(self) -> tuple[tuple[str, str], ...]:
        """Per-layer (mixer, ffn) kinds."""
        plan = []
        for i in range(self.num_layers):
            mixer = self.mixer_pattern[i % len(self.mixer_pattern)]
            if mixer == "ssd":
                ffn = "none"
            elif self.num_experts > 0 and i >= self.first_k_dense and (
                    i % self.moe_every == self.moe_offset):
                ffn = "moe"
            else:
                ffn = "mlp"
            plan.append((mixer, ffn))
        return tuple(plan)

    def stage_plan(self) -> tuple["Stage", ...]:
        """Group the layer plan into scannable stages.

        Returns stages of (block_kinds, repeat): a stage with repeat>1 is
        executed as a lax.scan over stacked params.  We look for a short
        periodic structure after an optional non-periodic prefix (e.g.
        deepseek's first dense layer, recurrentgemma's trailing partial
        pattern group).
        """
        plan = list(self.layer_plan())
        if not self.scan_layers:
            return (Stage(tuple(plan), 1),)      # fully unrolled (slice mode)
        stages: list[Stage] = []
        for prefix in range(0, min(4, len(plan)) + 1):
            body = plan[prefix:]
            if not body:
                continue
            for period in range(1, 5):
                if len(body) % period:
                    # allow a trailing remainder stage
                    rem = len(body) % period
                    main, tail = body[:-rem], body[-rem:]
                else:
                    main, tail = body, []
                if not main:
                    continue
                pat = main[:period]
                if all(main[i] == pat[i % period] for i in range(len(main))):
                    if prefix:
                        stages.append(Stage(tuple(plan[:prefix]), 1))
                    stages.append(Stage(tuple(pat), len(main) // period))
                    if tail:
                        stages.append(Stage(tuple(tail), 1))
                    return tuple(stages)
        return (Stage(tuple(plan), 1),)  # fallback: fully unrolled

    def num_params(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6ND)."""
        from repro.models import transformer  # local import to avoid cycle
        tree = transformer.abstract_params(self)
        return int(sum(math.prod(l.shape) for l in jax.tree.leaves(tree)))

    def active_params(self) -> int:
        """Active (per-token) params for MoE: replace routed experts by top_k."""
        n = self.num_params()
        if self.num_experts and self.top_k:
            expert = 3 * self.d_model * self.expert_d_ff
            n_moe_layers = sum(1 for _, f in self.layer_plan() if f == "moe")
            n -= n_moe_layers * (self.num_experts - self.top_k) * expert
        return n


@dataclasses.dataclass(frozen=True)
class Stage:
    blocks: tuple[tuple[str, str], ...]   # ((mixer, ffn), ...)
    repeat: int


# ---------------------------------------------------------------------------
# ParamDef machinery
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names per dim
    init: Callable[[jax.Array, tuple[int, ...], Any], Array] | None = None
    dtype: Any = None                     # default: cfg dtype

    def with_leading(self, n: int, axis_name: str = "layers") -> "ParamDef":
        return ParamDef((n,) + self.shape, (axis_name,) + self.axes,
                        self.init, self.dtype)


def normal_init(std: float = 0.02):
    def f(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
    return f


def zeros_init(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


def _is_def(x):
    return isinstance(x, ParamDef)


def init_tree(defs: Any, key: jax.Array, dtype: Any) -> Any:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    vals = []
    for k, d in zip(keys, leaves):
        init = d.init or normal_init()
        vals.append(init(k, d.shape, d.dtype or dtype))
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(defs: Any, dtype: Any) -> Any:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or dtype),
        defs, is_leaf=_is_def)


def axes_tree(defs: Any) -> Any:
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=_is_def)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def norm_def(d: int) -> ParamDef:
    # zero-centred scale (gemma convention: weight = 1 + scale)
    return ParamDef((d,), ("norm",), zeros_init)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: Array, head_dim: int, theta: float) -> tuple[Array, Array]:
    """positions (..., S) -> cos/sin (..., S, head_dim//2), f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x (..., S, H, D); cos/sin (..., S, 1, D/2) broadcastable."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def gelu(x: Array) -> Array:
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {
    "swiglu": jax.nn.silu,
    "geglu": gelu,
    "gelu": gelu,
}
