"""GQA attention: chunked online-softmax (flash-equivalent in XLA) + decode.

The training/prefill path is a two-level ``lax.scan`` over (q blocks, kv
blocks) with a streaming softmax, so the compiled HLO never materialises the
(S, T) score matrix — the memory_analysis of the dry-run therefore reflects
flash-attention behaviour.  The Pallas kernel in ``repro.kernels.
flash_attention`` is the TPU-target implementation of the same math and is
validated against ``repro.kernels.flash_attention.ref`` (which in turn is
validated against this module in tests).

Decode attends one new token against a (possibly ring-buffered) KV cache.
"""

from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import quant as Q
from repro.models.common import (ModelConfig, ParamDef, apply_rope,
                                 norm_def, normal_init, rmsnorm, rope_angles,
                                 zeros_init)

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig) -> dict:
    D, H, K, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    std_o = 0.02 / (2 * cfg.num_layers) ** 0.5
    defs = {
        "norm": norm_def(D),
        "wq": ParamDef((D, H, Dh), ("embed", "heads", "head_dim"), normal_init()),
        "wk": ParamDef((D, K, Dh), ("embed", "kv_heads", "head_dim"), normal_init()),
        "wv": ParamDef((D, K, Dh), ("embed", "kv_heads", "head_dim"), normal_init()),
        "wo": ParamDef((H, Dh, D), ("heads", "head_dim", "embed"), normal_init(std_o)),
    }
    if cfg.attn_bias:
        defs["bq"] = ParamDef((H, Dh), ("bias_heads", "head_dim"), zeros_init)
        defs["bk"] = ParamDef((K, Dh), ("kv_heads", "head_dim"), zeros_init)
        defs["bv"] = ParamDef((K, Dh), ("kv_heads", "head_dim"), zeros_init)
    return defs


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (full sequence)
# ---------------------------------------------------------------------------

def chunked_attention(q: Array, k: Array, v: Array, *,
                      q_positions: Array, kv_positions: Array,
                      causal: bool, window: int | None,
                      q_block: int, kv_block: int) -> Array:
    """q (B,S,H,Dh); k,v (B,T,K,Dh); positions (S,)/(T,).  Returns (B,S,H,Dh).

    Streaming softmax in f32; GQA via head-group folding.  Wrapped in
    jax.checkpoint per q-block so training memory stays O(S * Dh).
    """
    B, S, H, Dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qb = min(q_block, S)
    kb = min(kv_block, T)
    assert S % qb == 0 and T % kb == 0, (S, qb, T, kb)
    nq, nk = S // qb, T // kb
    scale = Dh ** -0.5

    qr = q.reshape(B, nq, qb, K, G, Dh).astype(jnp.bfloat16).swapaxes(0, 1)
    qpos = q_positions.reshape(nq, qb)
    kr = k.reshape(B, nk, kb, K, Dh).astype(jnp.bfloat16).swapaxes(0, 1)
    vr = v.reshape(B, nk, kb, K, Dh).astype(jnp.bfloat16).swapaxes(0, 1)
    kpos = kv_positions.reshape(nk, kb)

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def q_step(_, blk):
        qblk, qp = blk                          # (B,qb,K,G,Dh), (qb,)

        def kv_step(carry, kv):
            acc, m, l = carry                   # acc (B,K,G,qb,Dh) f32
            kblk, vblk, kp = kv
            s = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qb, kb), jnp.bool_)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window is not None:
                mask &= qp[:, None] - kp[None, :] < window
            mask &= kp[None, :] >= 0            # ring-buffer empty slots
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))      # (B,K,G,qb)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(jnp.bfloat16), vblk,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, K, G, qb, Dh), jnp.float32)
        m0 = jnp.full((B, K, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, qb), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (kr, vr, kpos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)        # (B,K,G,qb,Dh)

    _, outs = jax.lax.scan(q_step, None, (qr, qpos))
    # outs: (nq, B, K, G, qb, Dh) -> (B, S, H, Dh)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, Dh)
    return out


def plain_attention(q: Array, k: Array, v: Array, *, q_positions, kv_positions,
                    causal: bool, window: int | None) -> Array:
    """Reference O(S*T)-memory attention (small shapes / oracle)."""
    B, S, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    qr = q.reshape(B, S, K, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr.astype(jnp.float32),
                   k.astype(jnp.float32)) * (Dh ** -0.5)
    mask = jnp.ones((S, k.shape[1]), jnp.bool_)
    if causal:
        mask &= q_positions[:, None] >= kv_positions[None, :]
    if window is not None:
        mask &= q_positions[:, None] - kv_positions[None, :] < window
    mask &= kv_positions[None, :] >= 0
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Block forward (full sequence) and decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array          # (B, T, K, Dh)
    v: Array          # (B, T, K, Dh)
    pos: Array        # (B, T) absolute positions of cached keys, -1 = empty
    # Quantized POOLS only (cache_quant engines): per-row f32 scales,
    # (N, L, K) parallel to k/v with the head_dim axis reduced away.  None
    # (the default) is an empty pytree node, so every bf16 cache — monolithic
    # caches, gathered views, delta buffers — keeps its exact pre-quant
    # structure, jit traces and sharding trees included.
    k_scale: Any = None
    v_scale: Any = None


def init_kv_cache(cfg: ModelConfig, batch: int, length: int, local: bool) -> KVCache:
    if local and cfg.window is not None:
        length = min(length, cfg.window)
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    return KVCache(
        k=jnp.zeros((batch, length, K, Dh), cfg.dtype),
        v=jnp.zeros((batch, length, K, Dh), cfg.dtype),
        pos=jnp.full((batch, length), -1, jnp.int32),
    )


def _project_qkv(p: dict, x: Array, cfg: ModelConfig, positions: Array):
    """x (B,S,D), positions (B,S) -> q (B,S,H,Dh), k/v (B,S,K,Dh), roped."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.attn_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos[..., None, :], sin[..., None, :])
    k = apply_rope(k, cos[..., None, :], sin[..., None, :])
    return q, k, v


def _prefill_attention(q: Array, k: Array, v: Array, *, q_positions: Array,
                       kv_positions: Array, causal: bool, window: int | None,
                       cfg: ModelConfig) -> Array:
    """Prefill-path attention with per-backend impl selection.

    ``cfg.attn_prefill_impl``: "chunked" = the XLA two-level-scan online
    softmax (the oracle); "flash" = the positions-mode Pallas flash kernel
    (interpret mode off-TPU); None = flash on TPU, chunked elsewhere —
    tier-1 CPU numerics are unchanged by default.  Training (``attn_block``)
    always uses the chunked path: impl selection is serving-only.
    """
    impl = cfg.attn_prefill_impl
    if impl is None:
        impl = "flash" if jax.default_backend() == "tpu" else "chunked"
    if impl == "flash":
        from repro.kernels.flash_attention.ops import flash_attention_positions
        return flash_attention_positions(
            q, k, v, q_positions=q_positions, kv_positions=kv_positions,
            causal=causal, window=window)
    return chunked_attention(q, k, v, q_positions=q_positions,
                             kv_positions=kv_positions, causal=causal,
                             window=window, q_block=cfg.attn_q_block,
                             kv_block=cfg.attn_kv_block)


def _attn_forward(p: dict, x: Array, cfg: ModelConfig, positions: Array,
                  local: bool, *, prefill: bool = False
                  ) -> tuple[Array, Array, Array]:
    """Shared full-sequence body -> (x + attn(x), k, v) — single source of
    truth for the training forward AND prefill so they cannot diverge.
    ``prefill=True`` routes through ``_prefill_attention`` (impl-selected);
    the default chunked path keeps training untouched."""
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg, positions)
    if prefill:
        out = _prefill_attention(
            q, k, v, q_positions=positions[0], kv_positions=positions[0],
            causal=cfg.causal, window=cfg.window if local else None, cfg=cfg)
    else:
        out = chunked_attention(
            q, k, v,
            q_positions=positions[0], kv_positions=positions[0],
            causal=cfg.causal, window=cfg.window if local else None,
            q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return x + y, k, v


def attn_block(p: dict, x: Array, cfg: ModelConfig, *, local: bool,
               positions: Array | None = None) -> Array:
    """Pre-norm residual attention over a full sequence. x (B,S,D)."""
    B, S, D = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    out, _, _ = _attn_forward(p, x, cfg, positions, local)
    return out


def _scatter_kv(cache: KVCache, k: Array, v: Array, positions: Array,
                cfg: ModelConfig, local: bool, mesh, rules) -> KVCache:
    """Bulk-scatter roped span K/V into the cache at slot = position (ring
    slot = position % T for windowed layers).  Negative-position columns
    scatter out of bounds and are dropped."""
    B, S = positions.shape
    T = cache.k.shape[1]
    if local and cfg.window is not None and S > T:
        # ring buffer: only the last T POSITIONS survive a stepwise fill.
        # Mask by position rather than slicing columns — continuation spans
        # are right-padded, so the last T columns of a bucketed span are
        # not the last T real tokens (a column slice would drop recent real
        # K/V and keep padding).  Older positions scatter out of bounds;
        # their ring slots are overwritten by kept positions (every slot is
        # covered) or hold stale entries the window mask already excludes.
        pmax = positions.max(axis=1, keepdims=True)      # last real position
        positions = jnp.where(positions > pmax - T, positions, -1)
    slot = positions % T if (local and cfg.window is not None) else positions
    slot = jnp.where(positions >= 0, slot, T)
    b = jnp.arange(B)[:, None]
    kv_axes = ("act_batch", "act_kv_seq", "act_kv_heads", None)
    return KVCache(
        k=constrain(cache.k.at[b, slot].set(k.astype(cache.k.dtype),
                                            mode="drop"), kv_axes, mesh, rules),
        v=constrain(cache.v.at[b, slot].set(v.astype(cache.v.dtype),
                                            mode="drop"), kv_axes, mesh, rules),
        pos=cache.pos.at[b, slot].set(positions.astype(jnp.int32),
                                      mode="drop"),
    )


def attn_prefill(p: dict, x: Array, cache: KVCache, positions: Array,
                 cfg: ModelConfig, *, local: bool, continuation: bool = False,
                 mesh=None, rules=None) -> tuple[Array, KVCache]:
    """Prompt absorption: full-sequence attention + bulk KV-cache fill.

    x (B,S,D); positions (B,S) absolute positions, identical across the
    batch.  Negative positions are inert bucket padding: their K/V never
    enter the cache and attention masks them out, so a bucketed prefill is
    numerics-neutral per row.

    ``continuation=False`` (cold): requires a freshly initialised cache and
    a LEFT-padded span starting at position 0 — attention runs over the
    span's own K/V only (the fast path: no cache-length-sized reads).

    ``continuation=True`` (warm): the span is absorbed into an
    *already-populated* cache at offset positions (engine right-pads the
    span so padding never sits between the cached context and the new
    tokens).  The span K/V are scattered into the cache first, then the
    span queries attend over the **whole cache** — cached context and the
    span itself — with the same causal / window / empty-slot (pos = -1)
    masking the decode step uses, so a warm continuation reproduces
    cold-prefilling the concatenation (bitwise greedy tokens; logits to
    bf16 accumulation-order noise, see docs/RUNTIME.md).

    On-mesh (mesh/rules set) the refreshed KV cache is pinned to its
    logical-axis sharding so the bulk scatter does not un-shard it.
    """
    if not continuation:
        out, k, v = _attn_forward(p, x, cfg, positions, local, prefill=True)
        return out, _scatter_kv(cache, k, v, positions, cfg, local,
                                mesh, rules)

    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg, positions)
    cache = _scatter_kv(cache, k, v, positions, cfg, local, mesh, rules)
    # queries over the full cache: empty slots carry pos = -1 and are masked
    # exactly like the decode step's mask (cache.pos rows are identical
    # across the batch — batched sessions absorb identical position grids)
    out = _prefill_attention(
        q, cache.k, cache.v,
        q_positions=positions[0], kv_positions=cache.pos[0],
        causal=cfg.causal, window=cfg.window if local else None, cfg=cfg)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return x + y, cache


def _decode_chunk_len(cfg: ModelConfig, length: int) -> int:
    """Static streaming-chunk length: ``cfg.attn_decode_block`` halved until
    it divides the cache length (local windows can be shorter than 64)."""
    cb = min(cfg.attn_decode_block, length)
    while length % cb:
        cb //= 2
    return max(cb, 1)


def _decode_stream_chunk(carry, qr: Array, k_c: Array, v_c: Array,
                         pos_c: Array, index: Array, cfg: ModelConfig,
                         local: bool, k_s: Array | None = None,
                         v_s: Array | None = None):
    """Online-softmax update for ONE (B, cb) KV chunk of a decode attend.

    Every decode layout — monolithic cache, gathered paged view, and the
    kernel-first block-table read — pushes its chunks through this exact
    function, so layouts that produce elementwise-equal chunk data are
    bitwise-identical by construction; only chunk *provenance* differs.

    ``k_s``/``v_s`` (B, cb, K) set = quantized pool chunk: ``k_c``/``v_c``
    hold RAW quantized rows (cast to comp dtype — int8/fp8 values are exact
    in bf16) and the dequant is fused here, where the accumulator already
    runs in f32: the k-scale lands on the post-QK scores (a per-(slot,head)
    constant factors out of the Dh contraction exactly) and the v-scale
    folds into the softmax weights before the PV contraction — no
    cache-shaped f32 dequant copy ever exists (the swarmlint
    ``quant-scale-drift`` contract).  With both None the trace is
    byte-identical to the pre-quantization one.
    """
    m, l, acc = carry                       # (B,K,G), (B,K,G), (B,K,G,Dh) f32
    # bf16 operands + f32 accumulation: never materialise an f32 cache copy
    s = jnp.einsum("bkgd,btkd->bkgt", qr.astype(cfg.comp_dtype), k_c,
                   preferred_element_type=jnp.float32) * (cfg.head_dim ** -0.5)
    if k_s is not None:
        s = s * k_s.transpose(0, 2, 1)[:, :, None, :]       # (B,K,1,cb)
    mask = (pos_c <= index[:, None]) & (pos_c >= 0)
    if local and cfg.window is not None:
        mask &= index[:, None] - pos_c < cfg.window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l = l * corr + p.sum(axis=-1)
    if v_s is not None:
        p = p * v_s.transpose(0, 2, 1)[:, :, None, :]
    pv = jnp.einsum("bkgt,btkd->bkgd", p.astype(cfg.comp_dtype), v_c,
                    preferred_element_type=jnp.float32)
    acc = acc * corr[..., None] + pv
    return m_new, l, acc


def _decode_stream_init(B: int, cfg: ModelConfig):
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    G = cfg.num_heads // K
    # swarmlint: ignore[dtype-drift] flash-style (m, l, acc) softmax
    # accumulators live one decode step, not in the cache; bf16 running
    # max/sum loses low bits vs the reference softmax
    return (jnp.full((B, K, G), NEG_INF, jnp.float32),
            jnp.zeros((B, K, G), jnp.float32),  # swarmlint: ignore[dtype-drift] see above: one-step softmax accumulator
            jnp.zeros((B, K, G, Dh), jnp.float32))  # swarmlint: ignore[dtype-drift] see above: one-step softmax accumulator


def _decode_stream_finish(carry, B: int, cfg: ModelConfig, mesh, rules) -> Array:
    _, l, acc = carry
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(B, 1, cfg.num_heads, cfg.head_dim)
    return constrain(out, ("act_batch", None, "act_heads", "act_head_dim"),
                     mesh, rules)


def _decode_attend(q: Array, k_lin: Array, v_lin: Array, pos_lin: Array,
                   index: Array, cfg: ModelConfig, local: bool,
                   mesh, rules) -> Array:
    """One query token against a slot-linear (B,T) K/V view — shared by the
    monolithic cache and the gathered paged view, so the two layouts cannot
    diverge numerically (paged == monolithic is bitwise by construction
    when the views are elementwise equal).  Streams the view in
    ``cfg.attn_decode_block`` chunks through the same online softmax the
    kernel-first block-table path uses (see ``_decode_stream_chunk``)."""
    B, Tl = pos_lin.shape
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    G = cfg.num_heads // K
    qr = q.reshape(B, K, G, Dh)
    cb = _decode_chunk_len(cfg, Tl)
    nc = Tl // cb
    kr = k_lin.reshape(B, nc, cb, K, Dh).swapaxes(0, 1)
    vr = v_lin.reshape(B, nc, cb, K, Dh).swapaxes(0, 1)
    pr = pos_lin.reshape(B, nc, cb).swapaxes(0, 1)

    def step(carry, chunk):
        k_c, v_c, p_c = chunk
        return _decode_stream_chunk(carry, qr, k_c, v_c, p_c, index, cfg,
                                    local), None

    carry, _ = jax.lax.scan(step, _decode_stream_init(B, cfg), (kr, vr, pr))
    return _decode_stream_finish(carry, B, cfg, mesh, rules)


def attn_decode(p: dict, x: Array, cache: KVCache, index: Array,
                cfg: ModelConfig, *, local: bool, mesh=None, rules=None
                ) -> tuple[Array, KVCache]:
    """One-token decode. x (B,1,D); index (B,) absolute position of new token.

    On-mesh the one-row scatter and the attention contraction are pinned to
    the cache's logical-axis sharding, so a scanned decode keeps the KV
    cache sharded across steps (the scan carry would otherwise decay to
    whatever layout GSPMD propagates from the first step).
    """
    B = x.shape[0]
    T = cache.k.shape[1]
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k_new, v_new = _project_qkv(p, h, cfg, index[:, None])
    slot = index % T if (local and cfg.window is not None) else index
    b = jnp.arange(B)
    kv_axes = ("act_batch", "act_kv_seq", "act_kv_heads", None)
    cache = KVCache(
        k=constrain(cache.k.at[b, slot].set(k_new[:, 0].astype(cache.k.dtype)),
                    kv_axes, mesh, rules),
        v=constrain(cache.v.at[b, slot].set(v_new[:, 0].astype(cache.v.dtype)),
                    kv_axes, mesh, rules),
        pos=cache.pos.at[b, slot].set(index.astype(jnp.int32)),
    )
    out = _decode_attend(q, cache.k, cache.v, cache.pos, index, cfg, local,
                         mesh, rules).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return x + y, cache


# ---------------------------------------------------------------------------
# Paged (block-pool) variants: pool-shaped KVCache + per-slot block tables
# ---------------------------------------------------------------------------

PAGED_KV_AXES = ("act_pool", None, "act_kv_heads", None)
PAGED_SCALE_AXES = ("act_pool_scale", None, "act_kv_heads")


def init_paged_kv(cfg: ModelConfig, n_blocks: int, block_len: int,
                  cache_quant: str | None = None) -> KVCache:
    """Pool-shaped KV storage: k/v ``(n_blocks, block_len, K, Dh)``, pos
    ``(n_blocks, block_len)`` (-1 = empty).  Local-window layers share the
    same geometry — the window clamp happens at view time through the table
    slice, not in storage.  ``cache_quant`` set = k/v are stored int8/fp8
    with per-row f32 scales riding alongside (``quantize_rows(zeros)`` is
    ``(0, scale=0)``, so a zeroed quantized pool equals a scattered zeroed
    one)."""
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    dt = cfg.dtype if cache_quant is None else Q.qdtype(cache_quant)

    def scale():
        # one alloc per field — aliasing k_scale/v_scale to one buffer
        # trips the donated pool-reset jit ("donate the same buffer twice")
        return (None if cache_quant is None
                else jnp.zeros((n_blocks, block_len, K), jnp.float32))  # swarmlint: ignore[dtype-drift] quant scales MUST be f32 (see quant-scale-drift); K floats per L*K*Dh-element block is noise
    return KVCache(
        k=jnp.zeros((n_blocks, block_len, K, Dh), dt),
        v=jnp.zeros((n_blocks, block_len, K, Dh), dt),
        pos=jnp.full((n_blocks, block_len), -1, jnp.int32),
        k_scale=scale(), v_scale=scale(),
    )


def paged_view(pool: KVCache, table: Array,
               view_dtype: Any = jnp.bfloat16) -> KVCache:
    """Gather a slot-linear ``(B, nb*L, ...)`` view of the pool through the
    block table.  With the same writes applied, the view is elementwise
    equal to the monolithic cache of length nb*L — which is what makes the
    whole paged serving path bitwise-identical to the monolithic one.
    Sentinel (out-of-range) table entries clip to the last pool block:
    garbage reads that only ever feed an empty serve slot's own row.

    A quantized pool gathers its scales alongside and dequantizes HERE, so
    the view is always a plain ``cfg``-dtype monolithic cache — this is the
    gathered-view parity oracle for the fused-dequant decode paths, and the
    only place pool rows are materialised dequantized."""
    B, nb = table.shape
    L = pool.k.shape[1]
    flat = table.reshape(-1)
    k = jnp.take(pool.k, flat, axis=0, mode="clip")
    v = jnp.take(pool.v, flat, axis=0, mode="clip")
    pos = jnp.take(pool.pos, flat, axis=0, mode="clip")
    if pool.k_scale is not None:
        k = Q.dequantize_rows(k, jnp.take(pool.k_scale, flat, axis=0,
                                          mode="clip"), view_dtype)
        v = Q.dequantize_rows(v, jnp.take(pool.v_scale, flat, axis=0,
                                          mode="clip"), view_dtype)
    return KVCache(k=k.reshape(B, nb * L, *k.shape[2:]),
                   v=v.reshape(B, nb * L, *v.shape[2:]),
                   pos=pos.reshape(B, nb * L))


def paged_scatter_blocks(pool: KVCache, table: Array, lin: KVCache,
                         lo: Array, hi: Array, *,
                         window: int | None = None) -> KVCache:
    """Write the blocks covering position range [lo, hi) of a slot-linear
    cache back into the pool through the table.

    ``lin`` is the (B, T) linear cache the monolithic compute produced off
    a ``paged_view`` gather; ``lo``/``hi`` (B,) bound the positions that
    dispatch wrote (prefill span, decode steps).  Only the covering blocks
    are scattered — O(tokens written), and a refcount-shared prefix block
    (always below ``lo``) is NEVER written through, which is the paged
    allocator's copy-on-write invariant.  ``window`` set = ring-buffer
    layer: the write range is mapped to ring slots (with wrap).  Sentinel
    (out-of-range) table entries drop, so empty serve slots scatter
    nothing."""
    N, L = pool.k.shape[0], pool.k.shape[1]
    B, T = lin.pos.shape
    nb = T // L
    tbl = table[:, :nb]
    jpos = jnp.arange(nb, dtype=jnp.int32)[None] * L        # block starts
    if window is None:
        touched = (jpos < hi[:, None]) & (jpos + L > lo[:, None])
    else:
        span = hi - lo
        s0 = lo % T
        s1 = s0 + jnp.minimum(span, T)
        touched = (((jpos < s1[:, None]) & (jpos + L > s0[:, None]))
                   | ((jpos + T < s1[:, None])
                      & (jpos + T + L > s0[:, None])))     # ring wrap
    dst = jnp.where(touched, tbl, N).reshape(-1)            # (B*nb,)
    kb = lin.k.reshape(B * nb, L, *lin.k.shape[2:])
    vb = lin.v.reshape(B * nb, L, *lin.v.shape[2:])
    pb = lin.pos.reshape(B * nb, L)
    if pool.k_scale is not None:
        # quantize-at-scatter: per-row scales over the written (covering)
        # blocks only; untouched blocks — shared COW prefixes included —
        # keep their existing q/scale pairs byte-for-byte.
        quant = "int8" if pool.k.dtype == jnp.int8 else "fp8"
        kb, ks = Q.quantize_rows(kb, quant)
        vb, vs = Q.quantize_rows(vb, quant)
        return pool._replace(
            k=pool.k.at[dst].set(kb, mode="drop"),
            v=pool.v.at[dst].set(vb, mode="drop"),
            pos=pool.pos.at[dst].set(pb, mode="drop"),
            k_scale=pool.k_scale.at[dst].set(ks, mode="drop"),
            v_scale=pool.v_scale.at[dst].set(vs, mode="drop"),
        )
    return pool._replace(
        k=pool.k.at[dst].set(kb.astype(pool.k.dtype), mode="drop"),
        v=pool.v.at[dst].set(vb.astype(pool.v.dtype), mode="drop"),
        pos=pool.pos.at[dst].set(pb, mode="drop"),
    )


def paged_scatter_delta(pool: KVCache, table: Array, delta: KVCache,
                        p0: Array, *, window: int | None = None) -> KVCache:
    """Scatter a dispatch's decode delta buffer (``init_decode_delta``) into
    the pool through the table — O(steps) slot writes per row, no
    slot-linear intermediate.  Ring layers (``window`` set) wrap slots mod
    the view length; when ``steps`` exceeds the ring length only the LAST
    ring-length delta rows are kept (earlier writes were superseded
    in-ring; dropping them statically avoids the undefined ordering of
    duplicate-index scatters).  Sentinel table entries and unwritten delta
    rows (pos = -1) drop.  The resulting pool is elementwise-equal to what
    the gathered-view path's ``paged_scatter_blocks`` writeback produces."""
    N, L = pool.k.shape[0], pool.k.shape[1]
    B, steps = delta.pos.shape
    Tl = table.shape[1] * L
    k, v, pos = delta.k, delta.v, delta.pos
    off = jnp.arange(steps, dtype=jnp.int32)
    if window is not None and steps > Tl:
        k, v, pos = k[:, -Tl:], v[:, -Tl:], pos[:, -Tl:]
        off = off[-Tl:]
        steps = Tl
    slot = p0[:, None] + off[None]
    if window is not None:
        slot = slot % Tl
    blk = jnp.take_along_axis(table, slot // L, axis=1)     # (B, steps)
    flat = jnp.where((blk < N) & (pos >= 0), blk * L + slot % L, N * L)
    flat = flat.reshape(-1)
    kf = pool.k.reshape(N * L, *pool.k.shape[2:])
    vf = pool.v.reshape(N * L, *pool.v.shape[2:])
    pf = pool.pos.reshape(N * L)
    if pool.k_scale is not None:
        # the delta buffer stays bf16 (O(B*steps), not worth shrinking);
        # quantize its rows here so the dispatch boundary — not the write
        # path — decides the pool representation, same per-row function the
        # gathered path's paged_scatter_blocks applies to the same rows.
        quant = "int8" if pool.k.dtype == jnp.int8 else "fp8"
        k, ks = Q.quantize_rows(k, quant)
        v, vs = Q.quantize_rows(v, quant)
        ksf = pool.k_scale.reshape(N * L, *pool.k_scale.shape[2:])
        vsf = pool.v_scale.reshape(N * L, *pool.v_scale.shape[2:])
        ksf = ksf.at[flat].set(ks.reshape(B * steps, *ks.shape[2:]),
                               mode="drop")
        vsf = vsf.at[flat].set(vs.reshape(B * steps, *vs.shape[2:]),
                               mode="drop")
        kf = kf.at[flat].set(k.reshape(B * steps, *k.shape[2:]), mode="drop")
        vf = vf.at[flat].set(v.reshape(B * steps, *v.shape[2:]), mode="drop")
        pf = pf.at[flat].set(pos.reshape(-1), mode="drop")
        return pool._replace(
            k=kf.reshape(pool.k.shape), v=vf.reshape(pool.v.shape),
            pos=pf.reshape(pool.pos.shape),
            k_scale=ksf.reshape(pool.k_scale.shape),
            v_scale=vsf.reshape(pool.v_scale.shape))
    kf = kf.at[flat].set(k.reshape(B * steps, *k.shape[2:]).astype(kf.dtype),
                         mode="drop")
    vf = vf.at[flat].set(v.reshape(B * steps, *v.shape[2:]).astype(vf.dtype),
                         mode="drop")
    pf = pf.at[flat].set(pos.reshape(-1), mode="drop")
    return pool._replace(k=kf.reshape(pool.k.shape),
                         v=vf.reshape(pool.v.shape),
                         pos=pf.reshape(pool.pos.shape))


def init_decode_delta(cfg: ModelConfig, batch: int, steps: int) -> KVCache:
    """Per-dispatch decode write buffer for the kernel-first path: row ``t``
    holds the K/V the dispatch's step ``t`` produced (pos -1 = unwritten).
    O(B * steps) — the scan carry no longer holds any cache-length state."""
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    return KVCache(
        k=jnp.zeros((batch, steps, K, Dh), cfg.dtype),
        v=jnp.zeros((batch, steps, K, Dh), cfg.dtype),
        pos=jnp.full((batch, steps), -1, jnp.int32),
    )


def attn_decode_paged(p: dict, x: Array, pool: KVCache, table: Array,
                      delta: KVCache, index: Array, t: Array, p0: Array,
                      cfg: ModelConfig, *, local: bool, layer=None,
                      mesh=None, rules=None) -> tuple[Array, KVCache]:
    """One-token decode reading KV blocks IN PLACE through the block table.

    ``pool`` is the layer's block pool — a decode-scan *constant*, never
    materialised as a slot-linear view and never written here; ``table``
    (B, nb) is the block table, already sliced to the local window for
    windowed layers; ``delta`` holds this dispatch's decode writes (see
    ``init_decode_delta``); ``t`` is the scalar step number within the
    dispatch and ``p0`` (B,) the dispatch-start index (so index == p0 + t).

    The new token's K/V lands in delta row ``t`` first; each streamed pool
    chunk is then overlaid with the latest delta write per slot (ring slots
    for windowed layers), which makes the chunk data elementwise equal to
    the gathered-view path's slot-linear cache at step ``t`` — and the
    attend output bitwise equal, since both layouts stream through
    ``_decode_stream_chunk``.  On TPU the attend instead runs through the
    block-table Pallas kernel (``kernels/decode_attention``), validated
    against the gathered ref within tolerance.

    ``layer`` set = the pool leaves are repeat-stacked ``(R, N, L, ...)``
    (a stacked stage's scan constant) and ``layer`` is the stage scan's
    layer index: the gathers fold ``layer * N`` into their block ids
    instead of slicing a per-layer pool (which would copy the whole pool
    every decode step).

    Quantized pools (``pool.k_scale`` set) stream RAW int8/fp8 rows plus
    their per-row scale chunks and fuse the dequant into the accumulator
    (``_decode_stream_chunk``); the delta buffer stays bf16 and overlays
    with a unit scale.  Quantized-vs-gathered parity is budgeted, not
    bitwise: the fused path scales f32 scores where the oracle dequantizes
    rows to bf16 before the dot (see docs/RUNTIME.md "Quantized caches").
    """
    B = x.shape[0]
    stacked = layer is not None
    quantized = pool.k_scale is not None
    ksp = vsp = None
    if stacked:
        R, N, L = pool.k.shape[0], pool.k.shape[1], pool.k.shape[2]
        kp = pool.k.reshape((R * N,) + pool.k.shape[2:])
        vp = pool.v.reshape((R * N,) + pool.v.shape[2:])
        pp = pool.pos.reshape(R * N, L)
        if quantized:
            ksp = pool.k_scale.reshape((R * N,) + pool.k_scale.shape[2:])
            vsp = pool.v_scale.reshape((R * N,) + pool.v_scale.shape[2:])
        base = layer * N
    else:
        R, (N, L) = 1, (pool.k.shape[0], pool.k.shape[1])
        kp, vp, pp = pool.k, pool.v, pool.pos
        if quantized:
            ksp, vsp = pool.k_scale, pool.v_scale
        base = 0
    nb = table.shape[1]
    Tl = nb * L
    steps = delta.pos.shape[1]
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    G = cfg.num_heads // K

    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k_new, v_new = _project_qkv(p, h, cfg, index[:, None])
    delta = KVCache(
        k=delta.k.at[:, t].set(k_new[:, 0].astype(delta.k.dtype)),
        v=delta.v.at[:, t].set(v_new[:, 0].astype(delta.v.dtype)),
        pos=delta.pos.at[:, t].set(index.astype(jnp.int32)),
    )
    qr = q.reshape(B, K, G, Dh)
    ring = local and cfg.window is not None

    if jax.default_backend() == "tpu":
        from repro.kernels.decode_attention.ops import paged_decode_attention
        # stacked pools fold the layer offset into the table ids; sentinel
        # entries (>= N) stay sentinels for the flat pool (>= R * N).
        tbl = (jnp.where(table < N, table + base, R * N + 7)
               if stacked else table)
        out = paged_decode_attention(
            qr, kp, vp, pp, tbl, index,
            window=cfg.window if local else None,
            delta_k=delta.k, delta_v=delta.v, delta_pos=delta.pos, p0=p0,
            k_scale=ksp, v_scale=vsp)
        out = constrain(out.reshape(B, 1, cfg.num_heads, Dh),
                        ("act_batch", None, "act_heads", "act_head_dim"),
                        mesh, rules)
    else:
        cb = _decode_chunk_len(cfg, Tl)
        nc = Tl // cb
        kp_flat = kp.reshape(R * N * L, K, Dh)
        vp_flat = vp.reshape(R * N * L, K, Dh)
        pp_flat = pp.reshape(R * N * L)
        if quantized:
            ksp_flat = ksp.reshape(R * N * L, K)
            vsp_flat = vsp.reshape(R * N * L, K)
        # gather each chunk at BLOCK granularity when the chunk is
        # block-aligned (whole (L, K, Dh) rows, same access pattern as
        # paged_view's one-shot gather — ~2x over a per-slot row gather on
        # CPU); fall back to per-slot rows otherwise.  Same elements either
        # way, so the streamed chunks stay bitwise-identical.
        block_granular = cb % L == 0

        def step(carry, xs_c):
            k_s = v_s = None
            if block_granular:
                blks = xs_c                       # (cb // L,) chunk's blocks
                sl = (blks[:, None] * L
                      + jnp.arange(L, dtype=jnp.int32)[None]).reshape(-1)
                # block-level clip matches paged_view's sentinel semantics
                tb = jnp.minimum(jnp.take(table, blks, axis=1), N - 1) + base
                k_c = jnp.take(kp, tb, axis=0).reshape(B, cb, K, Dh)
                v_c = jnp.take(vp, tb, axis=0).reshape(B, cb, K, Dh)
                p_c = jnp.take(pp, tb, axis=0).reshape(B, cb)
                if quantized:
                    k_s = jnp.take(ksp, tb, axis=0).reshape(B, cb, K)
                    v_s = jnp.take(vsp, tb, axis=0).reshape(B, cb, K)
            else:
                sl = xs_c                         # (cb,) this chunk's slots
                blk = (jnp.minimum(jnp.take(table, sl // L, axis=1), N - 1)
                       + base)
                flat = blk * L + (sl % L)[None]              # (B, cb)
                k_c = jnp.take(kp_flat, flat, axis=0)        # (B, cb, K, Dh)
                v_c = jnp.take(vp_flat, flat, axis=0)
                p_c = jnp.take(pp_flat, flat, axis=0)        # (B, cb)
                if quantized:
                    k_s = jnp.take(ksp_flat, flat, axis=0)   # (B, cb, K)
                    v_s = jnp.take(vsp_flat, flat, axis=0)
            if quantized:
                # raw quantized rows cast to the compute dtype (int8/fp8
                # values are exact in bf16); the scales ride as separate
                # chunk operands and are applied inside the accumulator
                k_c = k_c.astype(cfg.comp_dtype)
                v_c = v_c.astype(cfg.comp_dtype)
            # overlay this dispatch's own writes: latest delta row per slot.
            # The index math is cheap (B, cb) ints; the gathers + full-width
            # wheres are ~2x the chunk's own traffic, so they run under a
            # cond — most chunks hold no written slot and skip them.
            if ring:
                rel = (sl[None] - p0[:, None]) % Tl
                d = rel + Tl * ((t - rel) // Tl)
            else:
                d = sl[None] - p0[:, None]
            valid = (d >= 0) & (d <= t)

            def overlay(args):
                k_c, v_c, p_c, k_s, v_s = args
                dc = jnp.clip(d, 0, steps - 1)
                k_d = jnp.take_along_axis(delta.k, dc[..., None, None],
                                          axis=1)
                v_d = jnp.take_along_axis(delta.v, dc[..., None, None],
                                          axis=1)
                p_d = jnp.take_along_axis(delta.pos, dc, axis=1)
                if quantized:
                    # delta rows are real bf16 values: overlay them verbatim
                    # and neutralise the slot's scale to 1 — the fused
                    # dequant then leaves them untouched
                    k_s = jnp.where(valid[..., None], 1.0, k_s)
                    v_s = jnp.where(valid[..., None], 1.0, v_s)
                return (jnp.where(valid[..., None, None],
                                  k_d.astype(k_c.dtype), k_c),
                        jnp.where(valid[..., None, None],
                                  v_d.astype(v_c.dtype), v_c),
                        jnp.where(valid, p_d, p_c), k_s, v_s)

            k_c, v_c, p_c, k_s, v_s = jax.lax.cond(
                valid.any(), overlay, lambda a: a,
                (k_c, v_c, p_c, k_s, v_s))
            return _decode_stream_chunk(carry, qr, k_c, v_c, p_c, index,
                                        cfg, local, k_s, v_s), None

        xs = (jnp.arange(nb, dtype=jnp.int32).reshape(nc, cb // L)
              if block_granular
              else jnp.arange(Tl, dtype=jnp.int32).reshape(nc, cb))
        carry, _ = jax.lax.scan(step, _decode_stream_init(B, cfg), xs)
        out = _decode_stream_finish(carry, B, cfg, mesh, rules)

    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype),
                   p["wo"].astype(x.dtype))
    return x + y, delta
