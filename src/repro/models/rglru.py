"""RG-LRU recurrent block (Griffin / RecurrentGemma [arXiv:2402.19427]).

Full-sequence path uses ``lax.associative_scan`` over the gated linear
recurrence h_t = a_t * h_{t-1} + b_t; decode is a single fused step on a
(B, W) f32 state.  Combined with local attention (1 attn : 2 recurrent), the
KV footprint is bounded by the window — which is what makes recurrentgemma a
long_500k-capable swarm member.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import (ModelConfig, ParamDef, gelu, norm_def,
                                 normal_init, rmsnorm, zeros_init)
from repro.models.ssm import _causal_conv, _causal_conv_step

Array = jax.Array

_C = 8.0  # Griffin's fixed gate sharpness


class RGLRUState(NamedTuple):
    h: Array      # (B, W) f32
    conv: Array   # (B, conv_width-1, W)


def rglru_defs(cfg: ModelConfig) -> dict:
    D, W = cfg.d_model, cfg.rnn_width or cfg.d_model

    def lam_init(key, shape, dtype):
        # a = sigmoid(lam)^c uniform-ish in [0.9, 0.999]
        u = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
        a_pow = u ** (1.0 / _C)
        return jnp.log(a_pow / (1 - a_pow)).astype(dtype)

    std_o = 0.02 / (2 * cfg.num_layers) ** 0.5
    return {
        "norm": norm_def(D),
        "w_in": ParamDef((D, W), ("embed", "ssm_inner"), normal_init()),
        "w_branch": ParamDef((D, W), ("embed", "ssm_inner"), normal_init()),
        "conv_w": ParamDef((cfg.rnn_conv_width, W), ("conv_width", "ssm_inner"), normal_init()),
        "conv_b": ParamDef((W,), ("ssm_inner",), zeros_init),
        "wa": ParamDef((W, W), ("embed", "ssm_inner"), normal_init()),
        "ba": ParamDef((W,), ("ssm_inner",), zeros_init),
        "wx": ParamDef((W, W), ("embed", "ssm_inner"), normal_init()),
        "bx": ParamDef((W,), ("ssm_inner",), zeros_init),
        "lam": ParamDef((W,), ("ssm_inner",), lam_init, jnp.float32),
        "w_out": ParamDef((W, D), ("ssm_inner", "embed"), normal_init(std_o)),
    }


def _gates(p: dict, u: Array):
    """u (B,L,W) post-conv -> (log_a, b) of the recurrence, f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wa"].astype(jnp.float32) + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["wx"].astype(jnp.float32) + p["bx"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, b


def rglru_block(p: dict, x: Array, cfg: ModelConfig) -> Array:
    """Full-sequence Griffin recurrent block. x (B,S,D)."""
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    u = h @ p["w_in"].astype(h.dtype)
    g = gelu(h @ p["w_branch"].astype(h.dtype))
    u, _ = _causal_conv(u, p["conv_w"], p["conv_b"])
    a, b = _gates(p, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, hseq = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (hseq.astype(x.dtype) * g) @ p["w_out"].astype(x.dtype)
    return x + y


def rglru_prefill(p: dict, x: Array, state: RGLRUState, positions: Array,
                  cfg: ModelConfig, mesh=None, rules=None, *,
                  continuation: bool = False) -> tuple[Array, RGLRUState]:
    """Prompt absorption: full-sequence associative scan that also returns
    the carried recurrent state for decode.

    positions (B,S): negative positions are inert bucket padding — their
    conv input is zeroed and their recurrence step forced to (a=1, b=0),
    so they pass the carried state through untouched.  Cold spans are
    left-padded (last column real); ``continuation=True`` spans are
    RIGHT-padded so the conv window of the first new token reaches into
    ``state.conv`` — the cached context tail — with no padding gap, and the
    conv tail is taken at the last *real* column.  The recurrence folds
    ``state.h`` into the first scan step either way (identity for the
    all-zero cold state) and trailing padding passes the final state
    through exactly, so warm continuation carries the same state cold
    absorption of the concatenation would.

    On-mesh the carried (B, W) state is pinned ``(act_batch,
    act_ssm_inner)`` so the decode scan keeps it sharded across steps.
    """
    valid = (positions >= 0)[..., None]                      # (B,S,1)
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    u = h @ p["w_in"].astype(h.dtype)
    g = gelu(h @ p["w_branch"].astype(h.dtype))
    u = jnp.where(valid, u, 0)
    tail_index = (valid[..., 0].sum(axis=1).astype(jnp.int32)
                  if continuation else None)
    u, conv_tail = _causal_conv(u, p["conv_w"], p["conv_b"], prev=state.conv,
                                tail_index=tail_index)
    a, b = _gates(p, u)
    a = jnp.where(valid, a, 1.0)
    b = jnp.where(valid, b, 0.0)
    # fold the incoming state into the first step: h_1 = a_1 h_0 + b_1
    b = b.at[:, 0].add(a[:, 0] * state.h)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, hseq = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (hseq.astype(x.dtype) * g) @ p["w_out"].astype(x.dtype)
    state = RGLRUState(
        h=constrain(hseq[:, -1], ("act_batch", "act_ssm_inner"), mesh, rules),
        conv=constrain(conv_tail, ("act_batch", None, "act_ssm_inner"),
                       mesh, rules))
    return x + y, state


def init_rglru_state(cfg: ModelConfig, batch: int) -> RGLRUState:
    W = cfg.rnn_width or cfg.d_model
    return RGLRUState(
        # swarmlint: ignore[dtype-drift] the RG-LRU recurrence h' = a*h + b*x
        # compounds per token; bf16 state drifts over long sequences and
        # breaks paged-vs-monolithic bitwise parity
        h=jnp.zeros((batch, W), jnp.float32),
        conv=jnp.zeros((batch, cfg.rnn_conv_width - 1, W), cfg.dtype),
    )


def rglru_decode(p: dict, x: Array, state: RGLRUState, cfg: ModelConfig,
                 mesh=None, rules=None) -> tuple[Array, RGLRUState]:
    """One-token decode. x (B,1,D)."""
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    u = h @ p["w_in"].astype(h.dtype)
    g = gelu(h @ p["w_branch"].astype(h.dtype))
    u, conv_tail = _causal_conv_step(u, p["conv_w"], p["conv_b"], state.conv)
    a, b = _gates(p, u)                      # (B,1,W)
    h_new = a[:, 0] * state.h + b[:, 0]
    y = (h_new[:, None].astype(x.dtype) * g) @ p["w_out"].astype(x.dtype)
    state = RGLRUState(
        h=constrain(h_new, ("act_batch", "act_ssm_inner"), mesh, rules),
        conv=constrain(conv_tail, ("act_batch", None, "act_ssm_inner"),
                       mesh, rules))
    return x + y, state
