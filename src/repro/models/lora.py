"""LoRA adapters (QLoRA-style efficient fine-tuning, paper Sec. II/IV-H).

Adapters target the attention q/v projections and the MLP up-projection.
``merge`` produces effective params W' = W + scale · A·B with the base
frozen (stop_gradient), so a loss differentiated w.r.t. the adapter tree
trains only the adapters — the paper's "teacher for distillation" pathway
onto edge SLMs.  Works transparently on scan-stacked (leading layer dim)
weights.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, init_tree, normal_init, zeros_init

Array = jax.Array

# key -> number of trailing dims that form the weight (in, out...) block
_TARGETS = {"wq": 3, "wv": 3, "w_up": 2}


def lora_defs(params: dict, rank: int = 8) -> dict:
    """Adapter defs parallel to (a subset of) a concrete params tree."""
    def walk(tree):
        out = {}
        if isinstance(tree, (list, tuple)):
            tree = {str(i): v for i, v in enumerate(tree)}
        for k, v in tree.items():
            if isinstance(v, (dict, list, tuple)) and not hasattr(v, "shape"):
                sub = walk(v)
                if sub:
                    out[k] = sub
            elif k in _TARGETS and hasattr(v, "shape"):
                base_nd = _TARGETS[k]
                if v.ndim < base_nd:
                    continue
                lead = v.shape[:v.ndim - base_nd]
                win = v.shape[v.ndim - base_nd]
                wout = v.shape[v.ndim - base_nd + 1:]
                lax = ("layers",) * len(lead)
                out[k] = {
                    "a": ParamDef(lead + (win, rank),
                                  lax + ("embed", None), normal_init(0.02)),
                    "b": ParamDef(lead + (rank,) + wout,
                                  lax + (None,) + ("ffn",) * len(wout),
                                  zeros_init),
                }
        return out
    return walk(params)


def init_lora(params: dict, key: jax.Array, rank: int = 8,
              dtype=jnp.float32) -> dict:
    return init_tree(lora_defs(params, rank), key, dtype)


def _delta(a: Array, b: Array, base_nd: int) -> Array:
    if base_nd == 2:
        return jnp.einsum("...ir,...ro->...io", a, b)
    return jnp.einsum("...ir,...rho->...iho", a, b)


def merge(params: dict, lora: dict, scale: float = 1.0,
          freeze_base: bool = True) -> dict:
    """Effective params: W + scale·A·B on adapted leaves."""
    def walk(ptree, ltree):
        if isinstance(ptree, (list, tuple)):
            return type(ptree)(
                walk(v, ltree.get(str(i), {}) if isinstance(ltree, dict)
                     else {}) for i, v in enumerate(ptree))
        out = {}
        for k, v in ptree.items():
            lsub = ltree.get(k) if isinstance(ltree, dict) else None
            if isinstance(v, (dict, list, tuple)) and not hasattr(v, "shape"):
                out[k] = walk(v, lsub or {})
            else:
                base = jax.lax.stop_gradient(v) if freeze_base else v
                if lsub is not None:
                    d = _delta(lsub["a"], lsub["b"], _TARGETS[k])
                    base = (base.astype(jnp.float32)
                            + scale * d.astype(jnp.float32)).astype(v.dtype)  # swarmlint: ignore[quant-scale-drift] `scale` is the LoRA merge strength, not a quant scale; one-time f32 param merge, no cache-shaped data
                out[k] = base
        return out
    return walk(params, lora)
