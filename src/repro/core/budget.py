"""Hard cloud-budget accounting (paper Eq. 13).

Budget_cloud^used accumulates the monetary cost of cloud-invoked queries over
an accounting window; when remaining budget is insufficient the gateway
disables cloud escalation (fallback to swarm/local).  ``charge_batch`` keeps
the prototype's strictly sequential semantics for a whole batch via
``lax.scan`` — a query is only admitted if budget remains *after* all
earlier queries in the batch.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class BudgetState(NamedTuple):
    total: Array     # () f32 — Budget_cloud^total for the window
    used: Array      # () f32 — Budget_cloud^used
    window_id: Array  # () i32 — accounting window (e.g. day index)


def init_budget(total: float, window_id: int = 0) -> BudgetState:
    return BudgetState(total=jnp.float32(total), used=jnp.float32(0.0),
                       window_id=jnp.int32(window_id))


def roll_window(state: BudgetState, window_id: Array) -> BudgetState:
    """Reset `used` when the accounting window advances."""
    fresh = window_id != state.window_id
    return BudgetState(
        total=state.total,
        used=jnp.where(fresh, 0.0, state.used),
        window_id=window_id.astype(jnp.int32),
    )


def remaining(state: BudgetState) -> Array:
    return jnp.maximum(state.total - state.used, 0.0)


def charge_batch(state: BudgetState, costs: Array, wants_cloud: Array
                 ) -> tuple[Array, BudgetState]:
    """Sequentially admit cloud requests while budget remains (Eq. 13).

    costs (B,) f32 estimated cloud cost per query; wants_cloud (B,) bool.
    Returns (admitted (B,) bool, new state).
    """
    def step(used, inp):
        cost, wants = inp
        ok = wants & (used + cost <= state.total)
        return used + jnp.where(ok, cost, 0.0), ok

    used_after, admitted = jax.lax.scan(
        step, state.used, (costs.astype(jnp.float32), wants_cloud))
    return admitted, state._replace(used=used_after)
