"""Difficulty / uncertainty quantification (paper Sec. IV-B, Eq. 2-4).

Paper-faithful definitions:

  Eq. 2  H_i(t)  = -(1/N) Σ_j P(t_j | t_<j, Q) · log P(t_j | t_<j, Q)
         — note: the *generated* token's probability, not full-distribution
         entropy.  We also provide `mode="distribution"` (full softmax
         entropy, normalised by log V) as a beyond-paper alternative.

  Eq. 3  V_i(Q) = (1/N) Σ_j Var(z_j^(k))      (top-k logits variance)

  Eq. 4  U_i(Q) = α · H_i(t) + (1-α) · V̂_i(Q),  V̂ normalised to [0,1]

The paper does not specify the V normalisation; we use the bounded squash
V̂ = V / (V + v_scale) (documented in EXPERIMENTS.md).  The fused Pallas
kernel `repro.kernels.swarm_uncertainty` computes the per-position terms in
one pass over vocab blocks; this module is the jnp reference / CPU path and
the public API.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class UncertaintyConfig:
    alpha: float = 0.5          # Eq. 4 mixing weight
    top_k: int = 10             # Eq. 3 top-k logits
    v_scale: float = 25.0       # V̂ = V / (V + v_scale)
    mode: str = "token"         # "token" (paper Eq. 2) | "distribution"
    invert_variance: bool = False  # beyond-paper: top-k logit variance is a
    # CONFIDENCE signal (peaked logits -> high Var); Eq. 4 as written adds it
    # positively to difficulty.  True uses (1 - V̂) so both terms point the
    # same way.  Default False = paper-faithful. See DESIGN.md §Fidelity.
    use_kernel: bool = False    # route through the Pallas kernel


def token_nent(logits: Array, tokens: Array) -> Array:
    """-p·log p of the chosen token. logits (..., N, V), tokens (..., N)."""
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)
    lp = jnp.take_along_axis(logp, tokens[..., None], axis=-1)[..., 0]
    p = jnp.exp(lp)
    return -p * lp                                     # (..., N), in [0, 1/e]


def dist_entropy(logits: Array) -> Array:
    """Full softmax entropy per position, normalised by log V to [0,1]."""
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)
    h = -jnp.sum(jnp.exp(logp) * logp, axis=-1)
    return h / jnp.log(logits.shape[-1])


def topk_logit_variance(logits: Array, k: int) -> Array:
    """Var over the top-k logits at each position (Eq. 3). (..., N)."""
    z, _ = jax.lax.top_k(logits.astype(jnp.float32), k)
    return jnp.var(z, axis=-1)


def sequence_entropy(logits: Array, tokens: Array, mask: Array | None = None,
                     mode: str = "token") -> Array:
    """Eq. 2 averaged over valid positions. Returns (...)."""
    per = token_nent(logits, tokens) if mode == "token" else dist_entropy(logits)
    if mask is None:
        return per.mean(axis=-1)
    m = mask.astype(jnp.float32)
    return (per * m).sum(-1) / jnp.maximum(m.sum(-1), 1.0)


def mean_logit_variance(logits: Array, k: int, mask: Array | None = None) -> Array:
    per = topk_logit_variance(logits, k)
    if mask is None:
        return per.mean(axis=-1)
    m = mask.astype(jnp.float32)
    return (per * m).sum(-1) / jnp.maximum(m.sum(-1), 1.0)


def normalise_variance(v: Array, v_scale: float) -> Array:
    return v / (v + v_scale)


def uncertainty_terms(logits: Array, tokens: Array,
                      cfg: UncertaintyConfig) -> tuple[Array, Array]:
    """Per-position (entropy, variance) terms of Eq. 2-3. (..., N) each.

    Split out of ``difficulty`` so the streaming serve path can accumulate
    terms token-by-token and combine them at request retirement.
    """
    if cfg.use_kernel:
        from repro.kernels.swarm_uncertainty import ops as kops
        return kops.uncertainty_terms(logits, tokens, k=cfg.top_k,
                                      mode=cfg.mode)
    h_per = (token_nent(logits, tokens) if cfg.mode == "token"
             else dist_entropy(logits))
    return h_per, topk_logit_variance(logits, cfg.top_k)


def combine_terms(h_mean, v_mean, cfg: UncertaintyConfig):
    """Eq. 4 from position-averaged terms -> U ∈ [0,1].

    Pure arithmetic, so it also works on host scalars — the streaming serve
    path combines per-request accumulators without a device round-trip.
    """
    if cfg.mode == "token":
        h_mean = h_mean * math.e        # rescale [0, 1/e] -> [0, 1]
    v_hat = normalise_variance(v_mean, cfg.v_scale)
    if cfg.invert_variance:
        v_hat = 1.0 - v_hat
    return cfg.alpha * h_mean + (1.0 - cfg.alpha) * v_hat


def difficulty(logits: Array, tokens: Array, cfg: UncertaintyConfig,
               mask: Array | None = None) -> Array:
    """Eq. 4 scalar difficulty score U ∈ [0,1]. logits (..., N, V)."""
    h_per, v_per = uncertainty_terms(logits, tokens, cfg)
    if mask is None:
        h, v = h_per.mean(-1), v_per.mean(-1)
    else:
        m = mask.astype(jnp.float32)
        d = jnp.maximum(m.sum(-1), 1.0)
        h, v = (h_per * m).sum(-1) / d, (v_per * m).sum(-1) / d
    return combine_terms(h, v, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def difficulty_jit(logits: Array, tokens: Array, cfg: UncertaintyConfig,
                   mask: Array | None = None) -> Array:
    return difficulty(logits, tokens, cfg, mask)
