"""Safety / policy risk classifier C_safety (paper Sec. IV-C, Eq. 5-6).

A compact bidirectional transformer (the paper suggests exactly this) built
on the shared model substrate: token embeddings -> 2 encoder blocks ->
masked mean-pool -> linear -> sigmoid risk score s ∈ [0,1].
R(Q) = 1[s > σ] (Eq. 6).

``train_step`` lets the examples/tests fit the classifier on the synthetic
safety workload so the routing experiments exercise a *learned* gate, not a
keyword oracle.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.common import (ModelConfig, ParamDef, init_tree,
                                 normal_init, zeros_init)

Array = jax.Array


def classifier_config(vocab_size: int = 2048, d_model: int = 128,
                      num_layers: int = 2) -> ModelConfig:
    return ModelConfig(
        name="c-safety", family="encoder",
        num_layers=num_layers, d_model=d_model,
        num_heads=4, num_kv_heads=4, head_dim=d_model // 4,
        d_ff=4 * d_model, vocab_size=vocab_size,
        causal=False, ffn_act="gelu",
        attn_q_block=64, attn_kv_block=64, scan_layers=True,
    )


def safety_defs(cfg: ModelConfig) -> dict:
    base = T.model_defs(cfg)
    base.pop("lm_head", None)
    base["head_w"] = ParamDef((cfg.d_model, 1), ("embed", None), normal_init())
    base["head_b"] = ParamDef((1,), (None,), zeros_init)
    return base


def init_safety(cfg: ModelConfig, key: jax.Array) -> dict:
    return init_tree(safety_defs(cfg), key, cfg.dtype)


def safety_score(params: dict, cfg: ModelConfig, tokens: Array,
                 mask: Array | None = None) -> Array:
    """tokens (B, S) -> s (B,) ∈ [0,1].  Eq. 5.  PAD=0 excluded from pool."""
    if mask is None:
        mask = (tokens > 0).astype(jnp.float32)
    x = T.embed_inputs(params, cfg, {"tokens": jnp.maximum(tokens, 0)})
    for sp, stage in zip(params["stages"], cfg.stage_plan()):
        x, _ = T._run_stage(sp, x, cfg, stage, 1, None, None)
    xf = x.astype(jnp.float32) * mask[..., None]
    pooled = xf.sum(1) / jnp.maximum(mask.sum(1, keepdims=True), 1.0)
    logit = pooled @ params["head_w"].astype(jnp.float32) + params["head_b"]
    return jax.nn.sigmoid(logit[..., 0])


def risk_flag(s: Array, sigma: float) -> Array:
    """Eq. 6: R(Q) = 1[s > σ]."""
    return (s > sigma).astype(jnp.int32)


def bce_loss(params: dict, cfg: ModelConfig, tokens: Array, labels: Array) -> Array:
    s = safety_score(params, cfg, tokens)
    s = jnp.clip(s, 1e-6, 1 - 1e-6)
    y = labels.astype(jnp.float32)
    return -(y * jnp.log(s) + (1 - y) * jnp.log(1 - s)).mean()


def make_trainer(cfg: ModelConfig, lr: float = 1e-2, steps: int = 200):
    """AdamW trainer for the classifier (tiny models need adaptive lr)."""
    from repro.training import optimizer as opt
    ocfg = opt.AdamWConfig(lr=lr, total_steps=steps, warmup_steps=10,
                           weight_decay=0.0)

    @jax.jit
    def step(params, state, tokens, labels):
        loss, grads = jax.value_and_grad(bce_loss)(params, cfg, tokens, labels)
        params, state, _ = opt.apply(grads, params, state, ocfg)
        return params, state, loss

    return step


@partial(jax.jit, static_argnames=("cfg", "lr"))
def train_step(params: dict, cfg: ModelConfig, tokens: Array, labels: Array,
               lr: float = 1e-3):
    """Plain-SGD step (kept for tests; prefer make_trainer)."""
    loss, grads = jax.value_and_grad(bce_loss)(params, cfg, tokens, labels)
    params = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                      ).astype(p.dtype), params, grads)
    return params, loss
