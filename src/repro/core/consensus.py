"""Uncertainty-weighted swarm consensus (paper Sec. IV-G, Eq. 14).

Answers are token-id sequences (pad = -1).  Clustering is exact-match in
token space — the same operation as the paper's lowercase/collapse-whitespace
string grouping, applied after tokenisation.  Each node j gets weight
w_j = clip(1 - U_j, w_min, 1); cluster score S(a) = Σ_{j∈a} w_j / Σ_k w_k.
The representative of the winning cluster is its longest member (paper's
tie-break).  Everything is vectorized jnp over the (small) peer dimension.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

PAD = -1
W_MIN_DEFAULT = 0.05  # paper's w_min


class ConsensusResult(NamedTuple):
    rep_index: Array      # () int32: index of representative answer
    best_score: Array     # () f32: S(a*) ∈ [0,1]
    cluster_id: Array     # (n,) int32: cluster of each answer
    scores: Array         # (n,) f32: S(cluster_of_j) per answer
    weights: Array        # (n,) f32: w_j


def _equality_matrix(answers: Array) -> Array:
    """answers (n, T) padded with PAD -> (n, n) bool exact-sequence equality."""
    eq = (answers[:, None, :] == answers[None, :, :])
    return eq.all(axis=-1)


def weighted_consensus(answers: Array, u: Array,
                       w_min: float = W_MIN_DEFAULT) -> ConsensusResult:
    """Eq. 14 over n peer answers. answers (n,T) int32, u (n,) ∈ [0,1]."""
    n = answers.shape[0]
    eq = _equality_matrix(answers)                         # (n,n)
    # cluster id = smallest index of an equal answer (equality is transitive
    # for exact match, so this is a proper partition)
    idx = jnp.arange(n)
    cluster = jnp.min(jnp.where(eq, idx[None, :], n), axis=1)

    w = jnp.clip(1.0 - u.astype(jnp.float32), w_min, 1.0)  # (n,)
    total = w.sum()
    # score of my cluster = sum of weights of members equal to me
    member_w = jnp.where(eq, w[None, :], 0.0)
    scores = member_w.sum(axis=1) / jnp.maximum(total, 1e-9)

    best_score = scores.max()
    # representative: longest answer within the best-scoring cluster
    lengths = (answers != PAD).sum(axis=1)
    in_best = scores >= best_score - 1e-9
    rep = jnp.argmax(jnp.where(in_best, lengths, -1))
    return ConsensusResult(rep_index=rep.astype(jnp.int32),
                           best_score=best_score,
                           cluster_id=cluster.astype(jnp.int32),
                           scores=scores, weights=w)


def batched_consensus(answers: Array, u: Array,
                      w_min: float = W_MIN_DEFAULT) -> ConsensusResult:
    """answers (B, n, T), u (B, n) -> batched ConsensusResult."""
    return jax.vmap(lambda a, uu: weighted_consensus(a, uu, w_min))(answers, u)


def consensus_decision(result: ConsensusResult, gamma: float) -> Array:
    """1 if the swarm answer is accepted (S(a*) >= γ), else escalate."""
    return (result.best_score >= gamma).astype(jnp.int32)
