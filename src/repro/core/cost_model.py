"""Cost and latency models (paper Sec. IV-D, Eq. 7-9) + Table I defaults."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CostParams:
    # Table I / Sec. V-A: Together.ai list price US$0.88 per 1M tokens
    c_cloud_per_token: float = 0.88e-6   # input == output price
    c_edge_per_token: float = 0.0        # energy-dominated, ≈0 monetary
    c_comm_per_byte: float = 1e-12       # proxy cost for swarm traffic
    bytes_per_token: float = 4.0         # answer-exchange encoding


def cost_cloud(t_prompt: Array, t_completion: Array,
               p: CostParams) -> Array:
    """Eq. 7: c_cloud * (T_cloud + T_prompt)."""
    return p.c_cloud_per_token * (t_prompt + t_completion)


def cost_swarm(t_edge: Array, bytes_exchanged: Array, p: CostParams) -> Array:
    """Eq. 8: c_edge * T_edge + c_comm * B(Q)."""
    return p.c_edge_per_token * t_edge + p.c_comm_per_byte * bytes_exchanged


def swarm_bytes(t_prompt: Array, t_answers: Array, p: CostParams) -> Array:
    """B(Q): request broadcast + collected answers, in bytes."""
    return p.bytes_per_token * (t_prompt + t_answers)


@dataclasses.dataclass(frozen=True)
class LatencyParams:
    """Calibrated against the paper's Table III measurements (seconds):
    edge-only mean 1.05 / p95 2.28; cloud-only mean 4.47 / p95 11.33, at
    ~14-token exchanges (short factoid prompts + answers)."""
    edge_per_token: float = 0.075        # SLM decode, desktop-class GPU
    edge_prefill: float = 0.080          # probe/prefill fixed part
    edge_jitter_sigma: float = 0.45      # lognormal multiplicative jitter
    cloud_per_token: float = 0.230       # 70B API decode incl. queueing
    wan_rtt_mean: float = 1.500          # WAN round-trip + API overhead
    wan_rtt_std: float = 4.500           # heavy-tail variability (p95 tail)
    comm_peer_mean: float = 0.150        # local wireless link, per message
    comm_peer_std: float = 0.080
    agg_overhead: float = 0.005          # L_agg at the gateway


def latency_edge(t_tokens: Array, p: LatencyParams) -> Array:
    return p.edge_prefill + p.edge_per_token * t_tokens


def latency_cloud(t_tokens: Array, wan_rtt: Array, p: LatencyParams) -> Array:
    return wan_rtt + p.cloud_per_token * t_tokens


def latency_retries(n_failed: Array | float, timeout_s: float,
                    backoff_s: Array | float) -> Array:
    """Realized latency of a retried cloud summon's FAILED attempts.

    Each failed attempt burns its full per-attempt deadline ``timeout_s``
    (an immediate transport error burns ~0, but the deadline is the
    conservative accounting the gateway uses for timeouts), and the
    retry loop sleeps ``backoff_s`` total between attempts (sum of the
    jittered exponential backoffs actually drawn).  Added on top of the
    successful attempt's Eq. 7-9 latency — or, when every attempt failed,
    it is the entire cloud-path latency the degraded query carries."""
    return n_failed * timeout_s + backoff_s


def latency_swarm(edge_lats: Array, comm_lats: Array, p: LatencyParams,
                  quorum: int | None = None) -> Array:
    """Eq. 9: max over self+peers of (L_edge^j + L_comm_j) + L_agg.

    quorum (beyond-paper straggler mitigation): wait only for the fastest
    `quorum` members instead of all — Eq. 9's max becomes the quorum-th
    order statistic.  See EXPERIMENTS.md §Perf.
    """
    per = edge_lats + comm_lats                   # (..., n_members)
    if quorum is None or quorum >= per.shape[-1]:
        tail = per.max(axis=-1)
    else:
        tail = jnp.sort(per, axis=-1)[..., quorum - 1]
    return tail + p.agg_overhead
