"""Privacy exposure metrics CER / TER / SER (paper Sec. VII-C, Eq. 15-17).

All three are computed from gateway decision logs and normalised so the
cloud-only architecture equals 1.0 (lower is better).  Edge-only is
identically 0 (no cloud calls) and omitted from Table V, matching the paper.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.router import CLOUD, CLOUD_SAFETY

Array = jnp.ndarray


class PrivacyMetrics(NamedTuple):
    cer: Array   # Eq. 15, normalised to cloud-only
    ter: Array   # Eq. 16
    ser: Array   # Eq. 17


def _is_exposed(decision: Array) -> Array:
    return (decision == CLOUD) | (decision == CLOUD_SAFETY)


def privacy_metrics(decision: Array, prompt_len: Array,
                    is_safety: Array) -> PrivacyMetrics:
    """decision (Q,) codes; prompt_len (Q,) chars (paper's token proxy);
    is_safety (Q,) bool marks the safety subset (SER proxy, Eq. 17)."""
    exposed = _is_exposed(decision).astype(jnp.float32)
    # Cloud-only baseline sends every prompt -> normalisers are 1.0-rates.
    cer = exposed.mean()                                          # Eq. 15
    plen = prompt_len.astype(jnp.float32)
    ter = (plen * exposed).sum() / jnp.maximum(plen.sum(), 1.0)   # Eq. 16
    saf = is_safety.astype(jnp.float32)
    ser = (saf * exposed).sum() / jnp.maximum(saf.sum(), 1.0)     # Eq. 17
    return PrivacyMetrics(cer=cer, ter=ter, ser=ser)


def reductions(m: PrivacyMetrics) -> dict:
    """Table V 'Reduction vs. Cloud-Only' column (%)."""
    return {
        "CER": float((1.0 - m.cer) * 100.0),
        "TER": float((1.0 - m.ter) * 100.0),
        "SER": float((1.0 - m.ser) * 100.0),
    }
