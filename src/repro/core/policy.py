"""State-action-environment view of routing (paper Sec. IV-A, Eq. 1).

The paper implements a threshold policy and leaves learned policies to
future work; we provide the reward signal (Eq. 1) and the historical
accuracy statistics used to approximate E[Acc(Q,d)] (Eq. 11) conditioned on
difficulty and risk — enough substrate for an offline-RL extension.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RewardWeights:
    acc: float = 1.0
    lat: float = 0.05       # per second
    cost: float = 1e4       # per dollar
    pol: float = 2.0        # policy-violation penalty


def reward(acc_hat: Array, latency: Array, cost: Array,
           violation: Array, w: RewardWeights) -> Array:
    """Eq. 1: r_t = λacc·Acc − λlat·Lat − λcost·Cost − λpol·1[violation]."""
    return (w.acc * acc_hat - w.lat * latency - w.cost * cost
            - w.pol * violation.astype(jnp.float32))


class AccuracyStats(NamedTuple):
    """Historical P(correct | difficulty bin, risk, action) (Eq. 11 approx)."""
    counts: Array    # (bins, 2, actions)
    correct: Array   # (bins, 2, actions)

    @staticmethod
    def init(bins: int = 8, actions: int = 5) -> "AccuracyStats":
        z = jnp.zeros((bins, 2, actions), jnp.float32)
        return AccuracyStats(counts=z, correct=z)

    def update(self, u: Array, risk: Array, action: Array,
               was_correct: Array) -> "AccuracyStats":
        bins = self.counts.shape[0]
        b = jnp.clip((u * bins).astype(jnp.int32), 0, bins - 1)
        idx = (b, risk.astype(jnp.int32), action.astype(jnp.int32))
        return AccuracyStats(
            counts=self.counts.at[idx].add(1.0),
            correct=self.correct.at[idx].add(was_correct.astype(jnp.float32)))

    def estimate(self, u: Array, risk: Array, action: Array,
                 prior: float = 0.5, strength: float = 2.0) -> Array:
        bins = self.counts.shape[0]
        b = jnp.clip((u * bins).astype(jnp.int32), 0, bins - 1)
        idx = (b, risk.astype(jnp.int32), action.astype(jnp.int32))
        c, k = self.correct[idx], self.counts[idx]
        return (c + prior * strength) / (k + strength)
