"""Distillation feedback loop (paper Sec. IV-H).

When D(Q) = cloud, the gateway logs (Q, context, M_cloud(Q)) into a
privacy-scrubbed buffer; logged examples later fine-tune edge SLM LoRA
adapters against the FM teacher (soft-target KL + hard-target CE), which
distils cloud behaviour back into the swarm.  The paper sketches this and
defers it to future work — here it is implemented end-to-end (see
examples/distill_loop.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import lora as lora_lib
from repro.models import transformer as T
from repro.models.common import ModelConfig

Array = jax.Array


@dataclasses.dataclass
class DistillBuffer:
    """Host-side ring buffer of escalated queries + teacher responses."""
    capacity: int = 4096
    items: list = dataclasses.field(default_factory=list)

    def log(self, query_tokens, teacher_tokens, meta: dict | None = None,
            scrub=None):
        """Respecting privacy policy: `scrub` strips/anonymises before storage."""
        if scrub is not None:
            query_tokens, teacher_tokens = scrub(query_tokens, teacher_tokens)
        self.items.append({"query": query_tokens, "teacher": teacher_tokens,
                           "meta": meta or {}})
        if len(self.items) > self.capacity:
            self.items.pop(0)

    def sample(self, rng, batch: int):
        idx = rng.choice(len(self.items), size=min(batch, len(self.items)),
                         replace=False)
        return [self.items[i] for i in idx]


def distill_loss(lora_params: dict, base_params: dict, cfg: ModelConfig,
                 batch: dict, teacher_logits: Array, *,
                 kl_weight: float = 0.5, temperature: float = 2.0) -> Array:
    """KL(teacher || student) at temperature + hard-target CE, LoRA-only."""
    params = lora_lib.merge(base_params, lora_params)
    logits, _ = T.forward(params, cfg, batch)
    sl = jax.nn.log_softmax(logits.astype(jnp.float32) / temperature, -1)
    tl = jax.nn.softmax(teacher_logits.astype(jnp.float32) / temperature, -1)
    mask = batch.get("loss_mask")
    kl = -(tl * sl).sum(-1)
    ce = -jnp.take_along_axis(
        jax.nn.log_softmax(logits.astype(jnp.float32), -1),
        batch["labels"][..., None], axis=-1)[..., 0]
    per = kl_weight * kl * temperature ** 2 + (1 - kl_weight) * ce
    if mask is not None:
        return (per * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return per.mean()


@partial(jax.jit, static_argnames=("cfg", "lr"))
def distill_step(lora_params: dict, base_params: dict, cfg: ModelConfig,
                 batch: dict, teacher_logits: Array, lr: float = 1e-3):
    loss, grads = jax.value_and_grad(distill_loss)(
        lora_params, base_params, cfg, batch, teacher_logits)
    lora_params = jax.tree.map(lambda p, g: p - lr * g, lora_params, grads)
    return lora_params, loss
