"""Threshold routing policy + Algorithm 1 (paper Sec. IV-F/IV-G).

Two-phase batched decision process, faithful to the prototype's sequential
semantics:

  Phase A (`route`): from difficulty U, risk R, WAN state, latency estimates
  and the hard cloud budget, assign each query LOCAL / SWARM / CLOUD /
  REFUSE.  Cloud admission is budget-sequential (Eq. 13 via
  ``budget.charge_batch``).

  Phase B (`post_consensus`): after the swarm round, queries whose best
  cluster score S(a*) < γ escalate to cloud (budget/WAN permitting) or keep
  the best-effort swarm answer (Algorithm 1 lines 15-23).

Decision codes double as the D(q) values of the privacy metrics (Eq. 15-17):
CLOUD and CLOUD_SAFETY both mean the raw prompt left the trust boundary.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.budget import BudgetState, charge_batch

Array = jax.Array

LOCAL, SWARM, CLOUD, CLOUD_SAFETY, REFUSE = 0, 1, 2, 3, 4
DECISION_NAMES = ("local", "swarm", "cloud", "cloud_safety", "refuse")


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    # Table I defaults
    tau_low: float = 0.35
    tau_high: float = 0.65
    sigma: float = 0.7
    peers_k: int = 3
    gamma: float = 0.6
    l_max: float = 0.5                # seconds
    # Sec. V-C "final experiments" preset
    @staticmethod
    def final() -> "RouterConfig":
        return RouterConfig(tau_low=0.08, tau_high=0.22, peers_k=2,
                            gamma=0.3, l_max=4.0)


class RouteResult(NamedTuple):
    decision: Array        # (B,) int32 decision codes
    risk: Array            # (B,) int32 R(Q)
    budget: BudgetState


def route(u: Array, safety_s: Array, *, cfg: RouterConfig,
          budget: BudgetState, wan_ok: Array,
          est_cloud_cost: Array,
          l_edge: Array | None = None,
          l_cloud: Array | None = None) -> RouteResult:
    """Phase A of Algorithm 1. All inputs (B,)-shaped; wan_ok () or (B,) bool."""
    B = u.shape[0]
    wan_ok = jnp.broadcast_to(jnp.asarray(wan_ok, bool), (B,))
    risk = (safety_s > cfg.sigma).astype(jnp.int32)            # Eq. 6

    wants_cloud = (risk == 1) | (u >= cfg.tau_high)
    # latency gating: local path violating L_max prefers cloud when cloud
    # meets the deadline (objective O1)
    if l_edge is not None and l_cloud is not None:
        bump = (l_edge > cfg.l_max) & (l_cloud <= cfg.l_max)
        wants_cloud |= bump

    admitted, budget = charge_batch(budget, est_cloud_cost,
                                    wants_cloud & wan_ok)
    is_cloud = wants_cloud & admitted & wan_ok

    # risk-flagged but cloud unavailable -> best-effort refusal (Alg.1 l.6)
    refuse = (risk == 1) & ~is_cloud
    # denied non-risk cloud aspirants fall back to swarm (O5 chain)
    fallback_swarm = wants_cloud & ~is_cloud & (risk == 0)
    is_swarm = ((u >= cfg.tau_low) & (u < cfg.tau_high) & (risk == 0)
                ) | fallback_swarm

    decision = jnp.full((B,), LOCAL, jnp.int32)
    decision = jnp.where(is_swarm, SWARM, decision)
    decision = jnp.where(is_cloud & (risk == 0), CLOUD, decision)
    decision = jnp.where(is_cloud & (risk == 1), CLOUD_SAFETY, decision)
    decision = jnp.where(refuse, REFUSE, decision)
    return RouteResult(decision=decision, risk=risk, budget=budget)


class PostConsensusResult(NamedTuple):
    decision: Array        # (B,) final decision codes
    use_swarm_answer: Array  # (B,) bool: keep best-effort swarm answer
    budget: BudgetState


def post_consensus(decision: Array, consensus_score: Array, *,
                   cfg: RouterConfig, budget: BudgetState, wan_ok: Array,
                   est_cloud_cost: Array) -> PostConsensusResult:
    """Phase B: escalate under-consensus swarm queries (Alg. 1 lines 15-23)."""
    B = decision.shape[0]
    wan_ok = jnp.broadcast_to(jnp.asarray(wan_ok, bool), (B,))
    was_swarm = decision == SWARM
    weak = was_swarm & (consensus_score < cfg.gamma)
    admitted, budget = charge_batch(budget, est_cloud_cost, weak & wan_ok)
    escalate = weak & admitted & wan_ok
    new_decision = jnp.where(escalate, CLOUD, decision)
    use_swarm_answer = was_swarm & ~escalate
    return PostConsensusResult(decision=new_decision,
                               use_swarm_answer=use_swarm_answer,
                               budget=budget)


def summoning_rate(decision: Array) -> Array:
    """Fraction escalated to the FM (metric 3, Sec. VI-B)."""
    cloud = (decision == CLOUD) | (decision == CLOUD_SAFETY)
    return cloud.astype(jnp.float32).mean()
