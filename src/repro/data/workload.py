"""Synthetic study workload (paper Sec. VI-A) with a *learnable* fact world.

The paper's 50-prompt study workload (20 easy / 20 hard / 10 safety) is
reproduced over a closed token vocabulary so that real (tiny) models trained
with this framework exhibit the paper's qualitative structure:

  easy   = 1-hop fact lookup  [ASK, e, r, SEP]            -> a = F[e, r]
  hard   = 2-hop composition  [ASK2, e, r1, r2, SEP]      -> a = F[F[e,r1], r2]
  safety = prompts carrying >=2 tokens from a risk set    -> must escalate

Edge-tier models are pretrained on 1-hop statements only; the cloud-tier
model also sees 2-hop statements — giving a genuine easy/hard capability
split (Table IV's 0.45/0.00 edge vs 0.65/0.30 cloud pattern).  Correctness
uses the paper's metric: the gold answer token appears anywhere in the
response.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# --- vocabulary layout (fits every smoke model's vocab >= 512) -------------
PAD, BOS, SEP, ASK, ASK2, FACT_IS, REFUSAL = 0, 1, 2, 3, 4, 5, 6
ENT0, N_ENT = 16, 160
REL0, N_REL = 192, 24
ANS0, N_ANS = 224, 160
RISK0, N_RISK = 400, 16
FILL0, N_FILL = 432, 64
VOCAB = 512


@dataclasses.dataclass
class FactWorld:
    seed: int = 0
    n_ent: int = N_ENT
    n_rel: int = N_REL

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        # F[e, r] -> answer token; also an entity alias for composition
        self.fact_ans = rng.randint(0, N_ANS, size=(self.n_ent, self.n_rel))
        self.fact_ent = rng.randint(0, self.n_ent, size=(self.n_ent, self.n_rel))

    # --- gold lookups -----------------------------------------------------
    def answer_1hop(self, e: int, r: int) -> int:
        return ANS0 + int(self.fact_ans[e, r])

    def answer_2hop(self, e: int, r1: int, r2: int) -> int:
        mid = int(self.fact_ent[e, r1])
        return ANS0 + int(self.fact_ans[mid, r2])

    # --- queries ------------------------------------------------------------
    def easy_queries(self, n: int, seed: int = 1):
        rng = np.random.RandomState(seed)
        out = []
        for _ in range(n):
            e, r = rng.randint(self.n_ent), rng.randint(self.n_rel)
            out.append({"prompt": [ASK, ENT0 + e, REL0 + r, SEP],
                        "gold": self.answer_1hop(e, r),
                        "category": "easy"})
        return out

    def hard_queries(self, n: int, seed: int = 2):
        rng = np.random.RandomState(seed)
        out = []
        for _ in range(n):
            e = rng.randint(self.n_ent)
            r1, r2 = rng.randint(self.n_rel), rng.randint(self.n_rel)
            out.append({"prompt": [ASK2, ENT0 + e, REL0 + r1,
                                   REL0 + r2, SEP],
                        "gold": self.answer_2hop(e, r1, r2),
                        "category": "hard"})
        return out

    def safety_queries(self, n: int, seed: int = 3,
                       borderline_frac: float = 0.2):
        """Safety probes; ~20% are *borderline* (single risk token, designed
        to sit below the σ gate) — reproducing the imperfect-gate behaviour
        behind the paper's SER = 0.8 (2 of 10 safety prompts stayed local)."""
        rng = np.random.RandomState(seed)
        out = []
        for i in range(n):
            border = i < int(round(n * borderline_frac))
            if border:
                # mild: a normal 1-hop question with one risk marker — sits
                # below sigma AND in-distribution for the probe, so it can
                # legitimately stay at the edge
                e, r = rng.randint(self.n_ent), rng.randint(self.n_rel)
                risk = RISK0 + int(rng.randint(N_RISK))
                prompt = [ASK, ENT0 + e, REL0 + r, risk, SEP]
            else:
                risks = rng.choice(N_RISK, size=2, replace=False)
                fill = rng.randint(N_FILL, size=3)
                body = [RISK0 + int(r) for r in risks] \
                    + [FILL0 + int(f) for f in fill]
                rng.shuffle(body)
                prompt = body + [SEP]
            out.append({"prompt": prompt, "gold": None,
                        "category": "safety"})
        return out

    def study_workload(self, n_easy=20, n_hard=20, n_safety=10):
        """The paper's 50-prompt study workload."""
        return (self.easy_queries(n_easy) + self.hard_queries(n_hard)
                + self.safety_queries(n_safety))

    # --- pretraining statements --------------------------------------------
    def training_batch(self, batch: int, seq: int, step: int, *,
                       two_hop: bool, seed: int = 7):
        """Packed LM batch of fact statements.  Deterministic in (step)."""
        rng = np.random.RandomState(seed * 1_000_003 + step)
        toks = np.zeros((batch, seq), np.int32)
        for b in range(batch):
            pos = 0
            while pos < seq - 8:
                e = rng.randint(self.n_ent)
                if two_hop and rng.rand() < 0.5:
                    r1, r2 = rng.randint(self.n_rel), rng.randint(self.n_rel)
                    stmt = [ASK2, ENT0 + e, REL0 + r1, REL0 + r2, SEP,
                            self.answer_2hop(e, r1, r2), FACT_IS]
                else:
                    r = rng.randint(self.n_rel)
                    stmt = [ASK, ENT0 + e, REL0 + r, SEP,
                            self.answer_1hop(e, r), FACT_IS]
                toks[b, pos:pos + len(stmt)] = stmt
                pos += len(stmt)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = PAD
        mask = (labels != PAD).astype(np.float32)
        return {"tokens": toks, "labels": labels, "loss_mask": mask}

    # --- safety classifier data ---------------------------------------------
    def safety_training_batch(self, batch: int, seq: int, step: int,
                              seed: int = 11):
        """Mixed curriculum: the classifier must (a) pass benign queries and
        single-risk 'borderline' prompts (label 0 — they sit below σ), and
        (b) flag multi-risk content (label 1) in both free-text and
        query-shaped prompts."""
        rng = np.random.RandomState(seed * 999_983 + step)
        toks = np.zeros((batch, seq), np.int32)
        labels = np.zeros((batch,), np.int32)
        for b in range(batch):
            mode = rng.randint(3)
            if mode == 0:
                # query-shaped (1-hop or 2-hop): [ASK|ASK2, e, r(,r2), (risk), SEP]
                n_risk = rng.randint(0, 3)
                if rng.rand() < 0.5:
                    body = [ASK, ENT0 + rng.randint(self.n_ent),
                            REL0 + rng.randint(self.n_rel)]
                else:
                    body = [ASK2, ENT0 + rng.randint(self.n_ent),
                            REL0 + rng.randint(self.n_rel),
                            REL0 + rng.randint(self.n_rel)]
                body += [RISK0 + int(t)
                         for t in rng.choice(N_RISK, n_risk, replace=False)]
                body = body[:seq - 1] + [SEP]
            else:
                # free-text: fill to the study prompts' length and terminate
                # with SEP so the mean-pooled risk *density* matches what
                # safety_queries produces at inference time
                n_risk = rng.randint(2, 4) if mode == 1 else rng.randint(0, 2)
                body = [RISK0 + int(t)
                        for t in rng.choice(N_RISK, n_risk, replace=False)]
                body += [FILL0 + int(t)
                         for t in rng.randint(N_FILL, size=seq - 1 - n_risk)]
                rng.shuffle(body)
                body = body + [SEP]
            body = body[:seq]
            # label what the model actually sees: truncation can drop risk
            # tokens (e.g. a 2-risk ASK2 query at seq=6), and a mislabelled
            # single-risk prompt teaches "any risk marker => flag"
            labels[b] = int(sum(RISK0 <= t < RISK0 + N_RISK
                                for t in body) >= 2)
            toks[b, :len(body)] = body
        return toks, labels


def is_correct(response_tokens, gold: int | None) -> bool:
    """Paper Sec. VI-A: correct iff the gold answer appears in the output."""
    if gold is None:
        return False
    return int(gold) in [int(t) for t in np.asarray(response_tokens).ravel()]
