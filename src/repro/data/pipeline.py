"""Deterministic, restart-safe data pipeline.

Batches are pure functions of (seed, step) — resuming from a checkpoint at
step N replays exactly the stream a non-failed run would have seen (no
state files to lose).  ``device_put_batch`` places the host batch against
the production mesh with batch sharded over ('pod','data'); under
multi-process JAX each host materialises only its addressable shard via
``jax.make_array_from_callback``.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.workload import FactWorld


@dataclasses.dataclass
class SyntheticLMPipeline:
    """Fact-world LM stream (see data/workload.py) + filler diversity."""
    batch: int
    seq: int
    two_hop: bool = False
    seed: int = 7
    world: FactWorld | None = None

    def __post_init__(self):
        self.world = self.world or FactWorld()

    def get_batch(self, step: int) -> dict[str, np.ndarray]:
        return self.world.training_batch(self.batch, self.seq, step,
                                         two_hop=self.two_hop, seed=self.seed)


def batch_sharding(mesh: Mesh) -> NamedSharding:
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    return NamedSharding(mesh, P(tuple(axes)))


def device_put_batch(batch: dict[str, np.ndarray], mesh: Mesh | None) -> dict:
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    sh = batch_sharding(mesh)
    out = {}
    for k, v in batch.items():
        out[k] = jax.make_array_from_callback(
            v.shape, sh, lambda idx, vv=v: vv[idx])
    return out
