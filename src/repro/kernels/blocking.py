"""Grid/block-shape selection shared by the Pallas kernels.

Every kernel here tiles a dimension ``D`` with a block ``b`` and a grid
of ``D // b`` steps, which is only legal when ``b`` divides ``D``.  The
historical policy ``b = min(cap, D)`` silently violated that for legal
serving geometries — e.g. a 640-slot cache (a multiple of the 64-slot
growth granule) against the decode kernel's 512 cap, or llama3's
128256-entry vocab against the uncertainty kernel's 2048 cap — and
tripped the kernels' divisibility asserts on TPU.

``snap_block`` keeps the cap as an upper bound but snaps down to the
largest divisor, so every geometry the engine can produce maps to a
legal grid.  The serving dimensions are 64/128-aligned (cache lengths
are multiples of 64, vocabularies multiples of 128), so snapped blocks
stay lane-aligned in practice.  ``tools/swarmlint``'s pallas-grid probe
sweeps every config's geometry through these choosers and fails the
build if a (dim, block) pair stops dividing.
"""
from __future__ import annotations


def snap_block(dim: int, cap: int) -> int:
    """Largest divisor of ``dim`` that is <= ``cap`` (>= 1)."""
    if dim <= 0:
        raise ValueError(f"cannot block a non-positive dim: {dim}")
    b = min(cap, dim)
    while dim % b:
        b -= 1
    return b


def decode_blocks(T: int, bt: int = 512) -> int:
    """Time-tile for ``decode_attention_pallas`` over a T-slot cache."""
    return snap_block(T, bt)


def flash_blocks(S: int, T: int, bq: int = 256,
                 bk: int = 256) -> tuple[int, int]:
    """(query, key) tiles for the flash-attention kernels."""
    return snap_block(S, bq), snap_block(T, bk)


def uncertainty_blocks(N: int, V: int, bn: int = 8,
                       bv: int = 2048) -> tuple[int, int]:
    """(row, vocab) tiles for ``uncertainty_pallas``."""
    return snap_block(N, bn), snap_block(V, bv)
