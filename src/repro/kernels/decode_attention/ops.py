"""Jitted wrapper for decode attention: Pallas on TPU, oracle elsewhere."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention import kernel as K
from repro.kernels.decode_attention import ref as R


@partial(jax.jit, static_argnames=("window", "bt", "force_pallas"))
def decode_attention(q, k, v, pos, index, *, window=None, bt=512,
                     force_pallas=False):
    if jax.default_backend() == "tpu" or force_pallas:
        return K.decode_attention_pallas(
            q, k, v, pos, index, window=window, bt=bt,
            interpret=jax.default_backend() != "tpu")
    return R.decode_attention_ref(q, k, v, pos, index, window=window)
