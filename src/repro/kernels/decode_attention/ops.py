"""Jitted wrapper for decode attention: Pallas on TPU, oracle elsewhere."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention import kernel as K
from repro.kernels.decode_attention import ref as R


@partial(jax.jit, static_argnames=("window", "bt", "force_pallas"))
def decode_attention(q, k, v, pos, index, *, window=None, bt=512,
                     force_pallas=False):
    if jax.default_backend() == "tpu" or force_pallas:
        return K.decode_attention_pallas(
            q, k, v, pos, index, window=window, bt=bt,
            interpret=jax.default_backend() != "tpu")
    return R.decode_attention_ref(q, k, v, pos, index, window=window)


@partial(jax.jit, static_argnames=("window", "force_pallas"))
def paged_decode_attention(q, k_pool, v_pool, pos_pool, table, index, *,
                           window=None, k_scale=None, v_scale=None,
                           delta_k=None, delta_v=None,
                           delta_pos=None, p0=None, force_pallas=False):
    """Block-table decode attention over a paged KV pool: the TPU kernel
    DMAs the slot's pool blocks through the scalar-prefetched table; the
    oracle gathers the linear view and reuses the monolithic reference.
    The optional delta operands overlay the current dispatch's own decode
    writes (see ``models.attention.attn_decode_paged``); the optional
    ``k_scale``/``v_scale`` (N, L, K) f32 leaves mark the pool as
    int8/fp8-quantized and both impls fold the dequant into the softmax
    read."""
    if jax.default_backend() == "tpu" or force_pallas:
        return K.paged_decode_attention_pallas(
            q, k_pool, v_pool, pos_pool, table, index, window=window,
            k_scale=k_scale, v_scale=v_scale,
            delta_k=delta_k, delta_v=delta_v, delta_pos=delta_pos, p0=p0,
            interpret=jax.default_backend() != "tpu")
    return R.paged_decode_attention_ref(q, k_pool, v_pool, pos_pool, table,
                                        index, window=window, k_scale=k_scale,
                                        v_scale=v_scale, delta_k=delta_k,
                                        delta_v=delta_v, delta_pos=delta_pos,
                                        p0=p0)
