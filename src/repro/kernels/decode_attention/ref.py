"""Pure-jnp oracle for the decode-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos, index, *, window=None):
    """q (B,K,G,D); k,v (B,T,K,D); pos (B,T); index (B,) -> (B,K,G,D)."""
    B, K, G, D = q.shape
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * D ** -0.5
    valid = (pos >= 0) & (pos <= index[:, None])
    if window is not None:
        valid &= index[:, None] - pos < window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, pos_pool, table, index, *,
                               window=None):
    """Block-table oracle: gather the slot-linear view of the pool
    (k_pool/v_pool (N,L,K,D), pos_pool (N,L), table (B,nb)) and run the
    monolithic reference over it — the same view the serving path's
    ``models.attention.paged_view`` assembles."""
    B, nb = table.shape
    L = k_pool.shape[1]
    flat = table.reshape(-1)
    k = jnp.take(k_pool, flat, axis=0, mode="clip").reshape(
        B, nb * L, *k_pool.shape[2:])
    v = jnp.take(v_pool, flat, axis=0, mode="clip").reshape(
        B, nb * L, *v_pool.shape[2:])
    pos = jnp.take(pos_pool, flat, axis=0, mode="clip").reshape(B, nb * L)
    return decode_attention_ref(q, k, v, pos, index, window=window)
