"""Pure-jnp oracle for the decode-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos, index, *, window=None):
    """q (B,K,G,D); k,v (B,T,K,D); pos (B,T); index (B,) -> (B,K,G,D)."""
    B, K, G, D = q.shape
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * D ** -0.5
    valid = (pos >= 0) & (pos <= index[:, None])
    if window is not None:
        valid &= index[:, None] - pos < window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, pos_pool, table, index, *,
                               window=None, k_scale=None, v_scale=None,
                               delta_k=None, delta_v=None,
                               delta_pos=None, p0=None):
    """Block-table oracle: gather the slot-linear view of the pool
    (k_pool/v_pool (N,L,K,D), pos_pool (N,L), table (B,nb)) and run the
    monolithic reference over it — the same view the serving path's
    ``models.attention.paged_view`` assembles.  Sentinel table entries
    (>= N) mask their whole block.  With the delta operands set
    (``delta_k``/``delta_v`` (B,S,K,D), ``delta_pos`` (B,S), ``p0`` (B,)),
    pool slots the dispatch rewrote — linear slots [p0, index], ring slots
    mod the view length for ``window`` layers — are masked and the delta
    rows are appended to the attended set instead (unwritten / future /
    in-ring-superseded rows masked), mirroring the kernel's two-phase
    read.  With ``k_scale``/``v_scale`` (N, L, K) the pool is quantized;
    the oracle gathers the scale rows alongside their blocks and
    materialises the dequantized view before attending — deliberately
    the thing the fused paths avoid, which is what makes it an oracle."""
    B, nb = table.shape
    N, L = k_pool.shape[0], k_pool.shape[1]
    flat = table.reshape(-1)
    k = jnp.take(k_pool, flat, axis=0, mode="clip").reshape(
        B, nb * L, *k_pool.shape[2:])
    v = jnp.take(v_pool, flat, axis=0, mode="clip").reshape(
        B, nb * L, *v_pool.shape[2:])
    if k_scale is not None:
        k_scale = jnp.take(k_scale, flat, axis=0, mode="clip").reshape(
            B, nb * L, *k_scale.shape[2:])
        v_scale = jnp.take(v_scale, flat, axis=0, mode="clip").reshape(
            B, nb * L, *v_scale.shape[2:])
        k = k.astype(jnp.float32) * k_scale[..., None]  # swarmlint: ignore[quant-scale-drift] oracle materialises the f32 dequantized view on purpose
        v = v.astype(jnp.float32) * v_scale[..., None]  # swarmlint: ignore[quant-scale-drift] oracle materialises the f32 dequantized view on purpose
    pos = jnp.take(pos_pool, flat, axis=0, mode="clip").reshape(B, nb * L)
    pos = jnp.where(jnp.repeat(table < N, L, axis=1), pos, -1)
    if delta_k is not None:
        Tl = nb * L
        sl = jnp.arange(Tl, dtype=jnp.int32)[None]
        if window is not None:
            covered = (sl - p0[:, None]) % Tl <= (index - p0)[:, None]
        else:
            covered = (sl >= p0[:, None]) & (sl <= index[:, None])
        pos = jnp.where(covered, -1, pos)
        dvalid = delta_pos <= index[:, None]
        if window is not None:
            dvalid &= delta_pos > index[:, None] - Tl    # superseded in-ring
        k = jnp.concatenate([k, delta_k.astype(k.dtype)], axis=1)
        v = jnp.concatenate([v, delta_v.astype(v.dtype)], axis=1)
        pos = jnp.concatenate([pos, jnp.where(dvalid, delta_pos, -1)], axis=1)
    return decode_attention_ref(q, k, v, pos, index, window=window)
