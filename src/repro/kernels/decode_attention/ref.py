"""Pure-jnp oracle for the decode-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos, index, *, window=None):
    """q (B,K,G,D); k,v (B,T,K,D); pos (B,T); index (B,) -> (B,K,G,D)."""
    B, K, G, D = q.shape
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * D ** -0.5
    valid = (pos >= 0) & (pos <= index[:, None])
    if window is not None:
        valid &= index[:, None] - pos < window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
