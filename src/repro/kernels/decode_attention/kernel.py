"""GQA decode-attention kernel: one query token vs a (ring) KV cache.

Decode is bandwidth-bound: arithmetic intensity ≈ 2 flops/byte of cache.
The kernel streams KV blocks through VMEM once per (batch, kv-head) pair
with all G query heads of the group resident, so cache bytes are read
exactly once (vs ≥2x for the unfused softmax path).  Ring-buffer validity
and the sliding window are handled via the cached absolute positions.

Grid: (B, K, T/bt), cache blocks innermost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, idx_ref, o_ref,
                   m_ref, l_ref, acc_ref,
                   *, bt: int, nt: int, window: int | None, scale: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # (bt, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    pos = pos_ref[0]                                  # (bt,) cached abs pos
    idx = idx_ref[0]                                  # () current position

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = (pos >= 0) & (pos <= idx)
    if window is not None:
        valid &= idx - pos < window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_old, l_old = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_old, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_old - m_new)
    l_ref[...] = l_old * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(t == nt - 1)
    def _emit():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def _paged_decode_kernel(tbl_ref, q_ref, k_ref, v_ref, pos_ref, idx_ref,
                         o_ref, m_ref, l_ref, acc_ref, *, bt: int, nt: int,
                         window: int | None, scale: float):
    """Same streaming-softmax body as ``_decode_kernel`` — the block table
    only changes WHERE each KV tile comes from (the BlockSpec index maps
    read ``tbl_ref``), not the math.  ``tbl_ref`` is scalar-prefetched so
    the DMA addresses are known before the body runs."""
    del tbl_ref
    _decode_kernel(q_ref, k_ref, v_ref, pos_ref, idx_ref, o_ref,
                   m_ref, l_ref, acc_ref, bt=bt, nt=nt, window=window,
                   scale=scale)


def paged_decode_attention_pallas(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array, pos_pool: jax.Array,
                                  table: jax.Array, index: jax.Array, *,
                                  window: int | None = None,
                                  interpret: bool = True) -> jax.Array:
    """Paged-cache decode attention: the KV cache lives in a block pool
    (``k_pool``/``v_pool`` (N, L, K, D), ``pos_pool`` (N, L)) and each
    batch row reads it through a block table (B, nb) of pool block ids.

    The grid iterates (B, K, nb) with the cache-block dim innermost, and
    the k/v/pos BlockSpec index maps dereference the scalar-prefetched
    table — ``table[b, t]`` picks the pool block to DMA — so the kernel
    streams exactly the slot's blocks through VMEM once per (batch,
    kv-head) pair, never materialising the gathered linear view the XLA
    path (``models.attention.paged_view``) builds.  Empty/invalid entries
    are masked by the pooled positions (pos = -1), identical to the
    monolithic kernel.
    """
    B, K, G, D = q.shape
    N, L = k_pool.shape[0], k_pool.shape[1]
    nb = table.shape[1]
    grid = (B, K, nb)
    kern = functools.partial(_paged_decode_kernel, bt=L, nt=nb,
                             window=window, scale=D ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,            # the block table
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, t, tbl: (b, h, 0, 0)),
            pl.BlockSpec((1, L, 1, D),
                         lambda b, h, t, tbl: (tbl[b, t], 0, h, 0)),
            pl.BlockSpec((1, L, 1, D),
                         lambda b, h, t, tbl: (tbl[b, t], 0, h, 0)),
            pl.BlockSpec((1, L), lambda b, h, t, tbl: (tbl[b, t], 0)),
            pl.BlockSpec((1,), lambda b, h, t, tbl: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t, tbl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(table, q.reshape(B, K, G, D), k_pool, v_pool, pos_pool, index)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            pos: jax.Array, index: jax.Array, *,
                            window: int | None = None, bt: int = 512,
                            interpret: bool = True) -> jax.Array:
    """q (B,K,G,D); k,v (B,T,K,D); pos (B,T); index (B,). -> (B,K,G,D)."""
    B, K, G, D = q.shape
    T = k.shape[1]
    bt = min(bt, T)
    assert T % bt == 0
    grid = (B, K, T // bt)
    kern = functools.partial(_decode_kernel, bt=bt, nt=T // bt,
                             window=window, scale=D ** -0.5)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, bt, 1, D), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, bt, 1, D), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, bt), lambda b, h, t: (b, t)),
            pl.BlockSpec((1,), lambda b, h, t: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(B, K, G, D), k, v, pos, index)
