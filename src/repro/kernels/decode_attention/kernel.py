"""GQA decode-attention kernel: one query token vs a (ring) KV cache.

Decode is bandwidth-bound: arithmetic intensity ≈ 2 flops/byte of cache.
The kernel streams KV blocks through VMEM once per (batch, kv-head) pair
with all G query heads of the group resident, so cache bytes are read
exactly once (vs ≥2x for the unfused softmax path).  Ring-buffer validity
and the sliding window are handled via the cached absolute positions.

Grid: (B, K, T/bt), cache blocks innermost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import blocking

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, idx_ref, o_ref,
                   m_ref, l_ref, acc_ref,
                   *, bt: int, nt: int, window: int | None, scale: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # (bt, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    pos = pos_ref[0]                                  # (bt,) cached abs pos
    idx = idx_ref[0]                                  # () current position

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = (pos >= 0) & (pos <= idx)
    if window is not None:
        valid &= idx - pos < window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_old, l_old = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_old, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_old - m_new)
    l_ref[...] = l_old * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(t == nt - 1)
    def _emit():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def _paged_decode_kernel(tbl_ref, *refs, L: int, nb: int,
                         window: int | None, scale: float, n_blocks: int,
                         ring: bool, quantized: bool):
    """Streaming-softmax body over a slot's pool blocks plus the dispatch's
    delta write buffer.

    Grid (B, K, nb + 1): steps ``t < nb`` stream pool block ``table[b, t]``
    (the BlockSpec index maps dereference the scalar-prefetched table, so
    the DMA address is known before the body runs); the final step attends
    the delta rows — this dispatch's own decode writes, which never touch
    the pool mid-scan — and emits.  Pool-side masks: cached-position
    validity (pos in [0, idx], window), sentinel table entries
    (``table[b, t] >= n_blocks`` kills the whole block; its DMA is clamped
    to a real block and the data discarded), and *covered* slots — slots
    this dispatch has rewritten, whose live value is the delta row (for
    ring layers the pre-wrap value can still pass the window test when the
    view is shorter than the window, so position masking alone is not
    enough).  Delta-side masks: unwritten rows (pos -1), future rows
    (pos > idx), and for ring layers rows superseded in-ring by a later
    write to the same slot (pos <= idx - ring length).

    With ``quantized`` the pool operands are int8/fp8 and two extra f32
    scale refs ride after v: the k-scale folds into the scores after the
    QK dot (a per-slot constant factors out of the D contraction exactly)
    and the v-scale folds into the softmax weights before the PV dot, so
    the dequantized cache is never materialised.  Delta rows stay bf16
    and skip both."""
    if quantized:
        (q_ref, k_ref, v_ref, ks_ref, vs_ref, pos_ref, idx_ref, p0_ref,
         dk_ref, dv_ref, dpos_ref, o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (q_ref, k_ref, v_ref, pos_ref, idx_ref, p0_ref,
         dk_ref, dv_ref, dpos_ref, o_ref, m_ref, l_ref, acc_ref) = refs
    b = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    idx = idx_ref[0]                                  # () current position
    p0 = p0_ref[0]                                    # () dispatch start
    ring_len = nb * L

    def update(k, v, valid, k_s=None, v_s=None):
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if k_s is not None:
            s = s * k_s[None, :]                     # fused k dequant
        s = jnp.where(valid[None, :], s, NEG_INF)
        m_old, l_old = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_old, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_old - m_new)
        l_ref[...] = l_old * corr + p.sum(axis=1)
        if v_s is not None:
            p = p * v_s[None, :]                     # fused v dequant
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(t < nb)
    def _pool_block():
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # (L, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        pos = pos_ref[0]                              # (L,) cached abs pos
        valid = (pos >= 0) & (pos <= idx)
        if window is not None:
            valid &= idx - pos < window
        valid &= tbl_ref[b, t] < n_blocks            # sentinel entry
        sl = t * L + jax.lax.broadcasted_iota(jnp.int32, (L,), 0)
        if ring:
            covered = (sl - p0) % ring_len <= idx - p0
        else:
            covered = (sl >= p0) & (sl <= idx)
        if quantized:
            update(k, v, valid & ~covered,
                   ks_ref[0, :, 0], vs_ref[0, :, 0])  # (L,) f32 rows
        else:
            update(k, v, valid & ~covered)

    @pl.when(t == nb)
    def _delta():
        k = dk_ref[0, :, 0, :].astype(jnp.float32)   # (S, D)
        v = dv_ref[0, :, 0, :].astype(jnp.float32)
        dpos = dpos_ref[0]                            # (S,) -1 = unwritten
        valid = (dpos >= 0) & (dpos <= idx)
        if window is not None:
            valid &= idx - dpos < window
        if ring:
            valid &= dpos > idx - ring_len           # superseded in-ring
        update(k, v, valid)
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_decode_attention_pallas(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array, pos_pool: jax.Array,
                                  table: jax.Array, index: jax.Array, *,
                                  window: int | None = None,
                                  k_scale: jax.Array | None = None,
                                  v_scale: jax.Array | None = None,
                                  delta_k: jax.Array | None = None,
                                  delta_v: jax.Array | None = None,
                                  delta_pos: jax.Array | None = None,
                                  p0: jax.Array | None = None,
                                  interpret: bool = True) -> jax.Array:
    """Paged-cache decode attention: the KV cache lives in a block pool
    (``k_pool``/``v_pool`` (N, L, K, D), ``pos_pool`` (N, L)) and each
    batch row reads it through a block table (B, nb) of pool block ids.

    The grid iterates (B, K, nb + 1) with the cache-block dim innermost,
    and the k/v/pos BlockSpec index maps dereference the scalar-prefetched
    table — ``table[b, t]`` picks the pool block to DMA — so the kernel
    streams exactly the slot's blocks through VMEM once per (batch,
    kv-head) pair, never materialising the gathered linear view the XLA
    path (``models.attention.paged_view``) builds.  Sentinel table entries
    (>= N, empty serve slots) are masked out wholesale; their DMA address
    is clamped in-range and the data discarded.

    ``delta_k``/``delta_v`` (B, S, K, D), ``delta_pos`` (B, S) and ``p0``
    (B,) carry the current dispatch's own decode writes (see
    ``models.attention.init_decode_delta``): the last grid step attends
    them, and pool slots the dispatch has rewritten — linear slots
    [p0, idx], ring slots for ``window`` layers, where the table is
    expected to be pre-sliced to the window so the ring length is the view
    length nb*L — are masked from the pool-side read.  Omitting the delta
    operands degrades to pure pool attention (a masked 1-row dummy rides
    the last grid step).

    With ``k_scale``/``v_scale`` (N, L, K) f32 the pool is quantized
    (int8/fp8) and the scale rows ride the same table-indexed DMA as
    their blocks; dequant is folded into the streaming softmax (see
    ``_paged_decode_kernel``), so VMEM traffic per block stays at the
    quantized byte width plus one f32 scale per row.  Delta operands
    stay bf16 regardless."""
    B, K, G, D = q.shape
    N, L = k_pool.shape[0], k_pool.shape[1]
    nb = table.shape[1]
    quantized = k_scale is not None
    if delta_k is None:
        dt = jnp.bfloat16 if quantized else k_pool.dtype
        delta_k = jnp.zeros((B, 1, K, D), dt)
        delta_v = jnp.zeros((B, 1, K, D), dt)
        delta_pos = jnp.full((B, 1), -1, jnp.int32)
        p0 = index + 1                   # covers nothing, masks nothing
    S = delta_pos.shape[1]
    grid = (B, K, nb + 1)
    kern = functools.partial(_paged_decode_kernel, L=L, nb=nb,
                             window=window, scale=D ** -0.5, n_blocks=N,
                             ring=window is not None, quantized=quantized)

    def blk(b, h, t, tbl):
        # clamp: the delta step (t == nb) and sentinel entries still need an
        # in-range DMA address; their data is masked in the body
        return (jnp.minimum(tbl[b, jnp.minimum(t, nb - 1)], N - 1), 0, h, 0)

    def blk_pos(b, h, t, tbl):
        return (jnp.minimum(tbl[b, jnp.minimum(t, nb - 1)], N - 1), 0)

    def blk_scale(b, h, t, tbl):
        return (jnp.minimum(tbl[b, jnp.minimum(t, nb - 1)], N - 1), 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, G, D), lambda b, h, t, tbl: (b, h, 0, 0)),
        pl.BlockSpec((1, L, 1, D), blk),
        pl.BlockSpec((1, L, 1, D), blk),
    ]
    operands = [q.reshape(B, K, G, D), k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, L, 1), blk_scale),
                     pl.BlockSpec((1, L, 1), blk_scale)]
        operands += [k_scale, v_scale]
    in_specs += [
        pl.BlockSpec((1, L), blk_pos),
        pl.BlockSpec((1,), lambda b, h, t, tbl: (b,)),
        pl.BlockSpec((1,), lambda b, h, t, tbl: (b,)),
        pl.BlockSpec((1, S, 1, D), lambda b, h, t, tbl: (b, 0, h, 0)),
        pl.BlockSpec((1, S, 1, D), lambda b, h, t, tbl: (b, 0, h, 0)),
        pl.BlockSpec((1, S), lambda b, h, t, tbl: (b, 0)),
    ]
    operands += [pos_pool, index, p0, delta_k, delta_v, delta_pos]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,            # the block table
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t, tbl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(table, *operands)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            pos: jax.Array, index: jax.Array, *,
                            window: int | None = None, bt: int = 512,
                            interpret: bool = True) -> jax.Array:
    """q (B,K,G,D); k,v (B,T,K,D); pos (B,T); index (B,). -> (B,K,G,D)."""
    B, K, G, D = q.shape
    T = k.shape[1]
    bt = blocking.decode_blocks(T, bt)
    assert T % bt == 0
    grid = (B, K, T // bt)
    kern = functools.partial(_decode_kernel, bt=bt, nt=T // bt,
                             window=window, scale=D ** -0.5)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, bt, 1, D), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, bt, 1, D), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, bt), lambda b, h, t: (b, t)),
            pl.BlockSpec((1,), lambda b, h, t: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(B, K, G, D), k, v, pos, index)
