"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qr = q.reshape(B, S, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, k.astype(jnp.float32)) * D ** -0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def flash_attention_positions_ref(q, k, v, *, q_positions, kv_positions,
                                  causal=True, window=None):
    """Positions-mode oracle: masks from explicit per-token positions
    (q_positions (S,), kv_positions (T,); negative = padding / empty slot),
    the same mask set the serving prefill uses (``models.attention``)."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qr = q.reshape(B, S, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, k.astype(jnp.float32)) * D ** -0.5
    mask = jnp.broadcast_to((kv_positions >= 0)[None, :], (S, T))
    if causal:
        mask &= q_positions[:, None] >= kv_positions[None, :]
    if window is not None:
        mask &= q_positions[:, None] - kv_positions[None, :] < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)
