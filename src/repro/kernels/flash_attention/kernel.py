"""Flash-attention prefill kernel (causal/local GQA) — TPU target.

Online-softmax over KV blocks with VMEM scratch carry; MXU-aligned tiles
(bq, bk multiples of 128 at production shapes, head_dim 64-256).  Causal
runs skip fully-masked KV blocks (the grid still visits them, but the body
is ``pl.when``-gated so no MXU work is issued) and the output tile is
written at the last *needed* block — the same block-skipping that makes a
real TPU flash kernel ~2x over dense for causal.

Grid: (B, H, S/bq, T/bk), KV innermost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import blocking

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, bq: int, bk: int, nk: int, causal: bool,
                  window: int | None, scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)

    q_start = i * bq
    k_start = j * bk

    last_needed = nk - 1
    if causal:
        last_needed = jnp.minimum(nk - 1, (q_start + bq - 1) // bk)
    needed = j <= last_needed
    if window is not None:
        needed &= (k_start + bk - 1) >= (q_start - window + 1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(needed)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_old, l_old = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_old, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_old - m_new)
        l_ref[...] = l_old * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == last_needed)
    def _emit():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def _flash_positions_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                            m_ref, l_ref, acc_ref, *, bq: int, bk: int,
                            nk: int, causal: bool, window: int | None,
                            scale: float):
    """Positions-mode flash body: the causal/window masks come from explicit
    per-token position operands instead of grid offsets, so the kernel can
    attend a span over a whole live cache (continuation prefill: cache slots
    carry absolute positions, -1 = empty) or over ring layouts where slot
    order is not position order.  No block skipping — validity is dynamic,
    every KV block is visited and masked."""
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)       # (bq, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    qp = qp_ref[0]                                   # (bq,) abs positions
    kp = kp_ref[0]                                   # (bk,) -1 = empty slot
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = jnp.broadcast_to((kp >= 0)[None, :], (bq, bk))
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window is not None:
        mask &= qp[:, None] - kp[None, :] < window
    s = jnp.where(mask, s, NEG_INF)

    m_old, l_old = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_old, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_old - m_new)
    l_ref[...] = l_old * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _emit():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention_positions_pallas(q: jax.Array, k: jax.Array,
                                     v: jax.Array, *, q_positions: jax.Array,
                                     kv_positions: jax.Array,
                                     causal: bool = True,
                                     window: int | None = None,
                                     bq: int = 256, bk: int = 256,
                                     interpret: bool = True) -> jax.Array:
    """q (B,S,H,D); k,v (B,T,K,D); q_positions (S,), kv_positions (T,)
    absolute positions shared across the batch (negative = inert padding /
    empty cache slot).  Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq, bk = blocking.flash_blocks(S, T, bq, bk)
    assert S % bq == 0 and T % bk == 0
    grid = (B, H, S // bq, T // bk)
    kern = functools.partial(
        _flash_positions_kernel, bq=bq, bk=bk, nk=T // bk, causal=causal,
        window=window, scale=D ** -0.5)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bq), lambda b, h, i, j: (0, i)),
            pl.BlockSpec((1, bk), lambda b, h, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_positions.reshape(1, S).astype(jnp.int32),
      kv_positions.reshape(1, T).astype(jnp.int32))


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           bq: int = 256, bk: int = 256,
                           interpret: bool = True) -> jax.Array:
    """q (B,S,H,D); k,v (B,T,K,D) with H = K*G. Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq, bk = blocking.flash_blocks(S, T, bq, bk)
    assert S % bq == 0 and T % bk == 0
    grid = (B, H, S // bq, T // bk)
    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=T // bk, causal=causal,
        window=window, scale=D ** -0.5)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
