"""Jitted wrapper for flash attention: Pallas on TPU, oracle elsewhere."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref as R


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "force_pallas"))
def flash_attention(q, k, v, *, causal=True, window=None, bq=256, bk=256,
                    force_pallas=False):
    if jax.default_backend() == "tpu" or force_pallas:
        return K.flash_attention_pallas(
            q, k, v, causal=causal, window=window, bq=bq, bk=bk,
            interpret=jax.default_backend() != "tpu")
    return R.flash_attention_ref(q, k, v, causal=causal, window=window)


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "force_pallas"))
def flash_attention_positions(q, k, v, *, q_positions, kv_positions,
                              causal=True, window=None, bq=256, bk=256,
                              force_pallas=False):
    """Positions-mode flash attention: masks from explicit per-token
    positions (negative = padding / empty cache slot), so a span can attend
    over a whole live cache — the serving prefill's continuation case."""
    if jax.default_backend() == "tpu" or force_pallas:
        return K.flash_attention_positions_pallas(
            q, k, v, q_positions=q_positions, kv_positions=kv_positions,
            causal=causal, window=window, bq=bq, bk=bk,
            interpret=jax.default_backend() != "tpu")
    return R.flash_attention_positions_ref(
        q, k, v, q_positions=q_positions, kv_positions=kv_positions,
        causal=causal, window=window)
