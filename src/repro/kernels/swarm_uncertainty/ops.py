"""Jitted public wrapper: picks the Pallas kernel on TPU, oracle elsewhere."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.swarm_uncertainty import kernel as K
from repro.kernels.swarm_uncertainty import ref as R


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("k", "mode", "force_pallas"))
def uncertainty_terms(logits: jax.Array, tokens: jax.Array, *, k: int = 10,
                      mode: str = "token", force_pallas: bool = False):
    """Per-position (entropy_term, topk_variance). logits (..., N, V)."""
    shape = logits.shape
    lg = logits.reshape((-1,) + shape[-2:])
    tk = tokens.reshape((-1, shape[-2]))
    if _on_tpu() or force_pallas:
        h, v, hd = K.uncertainty_pallas(lg, tk, k=k, interpret=not _on_tpu())
    else:
        h, v, hd = R.uncertainty_ref(lg, tk, k=k)
    h_out = h if mode == "token" else hd
    return h_out.reshape(shape[:-1]), v.reshape(shape[:-1])
