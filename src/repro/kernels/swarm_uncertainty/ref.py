"""Pure-jnp oracle for the fused uncertainty kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def uncertainty_ref(logits: jax.Array, tokens: jax.Array, *, k: int = 10):
    """logits (B,N,V), tokens (B,N) -> (h_token, v_topk, h_dist), (B,N) f32."""
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)
    lp = jnp.take_along_axis(logp, tokens[..., None], axis=-1)[..., 0]
    p = jnp.exp(lp)
    h_token = -p * lp

    z, _ = jax.lax.top_k(lf, k)
    v_topk = jnp.var(z, axis=-1)

    h_dist = -jnp.sum(jnp.exp(logp) * logp, axis=-1) / jnp.log(lf.shape[-1])
    return h_token, v_topk, h_dist
