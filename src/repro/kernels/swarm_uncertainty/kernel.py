"""Fused uncertainty-probe kernel (paper Eq. 2-3) — the SWARM-LLM hot spot.

For every decoded position the gateway needs (i) the chosen-token
-p·log p term (Eq. 2), optionally full-distribution entropy, and (ii) the
top-k logit variance (Eq. 3).  Done naively that is 3 passes over the
(N, V) logits in HBM (softmax, gather, top_k) — V is up to 256k for the
assigned archs, so the probe is pure memory traffic.  This kernel streams
vocab blocks through VMEM once and keeps all running statistics
(online max / sum-exp / Σz·e^z / chosen logit / top-k buffer) in VMEM
scratch: a single HBM read of the logits, vocab-block tiles aligned to the
(8,128) VPU lanes.

Grid: (B, N/bn, V/bv), vocab innermost (sequential reduction on TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import blocking

NEG_INF = -1e30


def _select_topk(cand: jax.Array, k: int) -> jax.Array:
    """Row-wise top-k of cand (R, C) via k unrolled max+mask steps (no sort —
    Mosaic-friendly, exact under ties)."""
    R, C = cand.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (R, C), 1)
    out = []
    work = cand
    for _ in range(k):
        cur = work.max(axis=1)
        am = work.argmax(axis=1)
        out.append(cur)
        work = jnp.where(cols == am[:, None], NEG_INF, work)
    return jnp.stack(out, axis=1)  # (R, k)


def _uncertainty_kernel(logits_ref, tokens_ref, h_ref, v_ref, hd_ref,
                        m_ref, l_ref, s_ref, chosen_ref, topk_ref,
                        *, k: int, bv: int, nv: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        s_ref[...] = jnp.zeros_like(s_ref)
        chosen_ref[...] = jnp.full_like(chosen_ref, NEG_INF)
        topk_ref[...] = jnp.full_like(topk_ref, NEG_INF)

    blk = logits_ref[0].astype(jnp.float32)            # (bn, bv)
    tok = tokens_ref[0]                                # (bn,)

    # --- online logsumexp (+ Σ z·e^z for distribution entropy) ---
    m_old, l_old, s_old = m_ref[...], l_ref[...], s_ref[...]
    m_new = jnp.maximum(m_old, blk.max(axis=1))
    corr = jnp.exp(m_old - m_new)
    e = jnp.exp(blk - m_new[:, None])
    l_ref[...] = l_old * corr + e.sum(axis=1)
    s_ref[...] = s_old * corr + (e * blk).sum(axis=1)
    m_ref[...] = m_new

    # --- chosen-token logit (Eq. 2 numerator) ---
    lo = j * bv
    idx_local = jnp.clip(tok - lo, 0, bv - 1)
    val = jnp.take_along_axis(blk, idx_local[:, None], axis=1)[:, 0]
    in_blk = (tok >= lo) & (tok < lo + bv)
    chosen_ref[...] = jnp.where(in_blk, val, chosen_ref[...])

    # --- running top-k merge (Eq. 3) ---
    blk_topk = _select_topk(blk, k)
    cand = jnp.concatenate([topk_ref[...], blk_topk], axis=1)
    topk_ref[...] = _select_topk(cand, k)

    @pl.when(j == nv - 1)
    def _finalize():
        m, l, s = m_ref[...], l_ref[...], s_ref[...]
        log_l = jnp.log(jnp.maximum(l, 1e-30))
        logp = chosen_ref[...] - m - log_l
        p = jnp.exp(logp)
        h_ref[0] = -p * logp                               # Eq. 2 per-position
        hd_ref[0] = (log_l + m - s / jnp.maximum(l, 1e-30)) \
            / jnp.log(jnp.float32(nv * bv))                # full-dist entropy
        t = topk_ref[...]
        mean = t.mean(axis=1)
        v_ref[0] = (t * t).mean(axis=1) - mean * mean      # Eq. 3 per-position


def uncertainty_pallas(logits: jax.Array, tokens: jax.Array, *, k: int = 10,
                       bn: int = 8, bv: int = 2048,
                       interpret: bool = True):
    """logits (B,N,V), tokens (B,N) -> (h_token, v_topk, h_dist), each (B,N)."""
    B, N, V = logits.shape
    bn, bv = blocking.uncertainty_blocks(N, V, bn, bv)
    assert N % bn == 0 and V % bv == 0, (N, bn, V, bv)
    grid = (B, N // bn, V // bv)
    kern = functools.partial(_uncertainty_kernel, k=k, bv=bv, nv=V // bv)
    out_shape = [jax.ShapeDtypeStruct((B, N), jnp.float32)] * 3
    h, v, hd = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, bv), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, bn), lambda b, i, j: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bn), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bn), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bn), lambda b, i, j: (b, i)),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bn,), jnp.float32),       # m
            pltpu.VMEM((bn,), jnp.float32),       # l
            pltpu.VMEM((bn,), jnp.float32),       # s = Σ z e^z
            pltpu.VMEM((bn,), jnp.float32),       # chosen logit
            pltpu.VMEM((bn, k), jnp.float32),     # top-k buffer
        ],
        interpret=interpret,
    )(logits, tokens)
    return h, v, hd
