"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Axes:

  pod   — failure/locality domain (the paper's "edge site"); crosses the
          slow (DCN/WAN-class) links where gradient compression applies
  data  — FSDP / batch parallelism (fast ICI)
  model — tensor/expert parallelism (fast ICI)
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def elastic_mesh(model_parallel: int = 16, pods: int = 1):
    """Build the largest (pod, data, model) mesh the live devices support —
    restore-time elasticity: a checkpoint re-shards onto whatever is alive."""
    n = len(jax.devices())
    model_parallel = min(model_parallel, n)
    while n % model_parallel:
        model_parallel //= 2
    rest = n // model_parallel
    pods = min(pods, rest)
    while rest % pods:
        pods -= 1
    data = rest // pods
    if pods > 1:
        return jax.make_mesh((pods, data, model_parallel),
                             ("pod", "data", "model"))
    return jax.make_mesh((data, model_parallel), ("data", "model"))


def serving_mesh(model_parallel: int = 1):
    """(data, model) mesh over the live devices for the serving runtime.

    ``model_parallel`` is clamped down to the nearest divisor of the device
    count; the remaining devices become the 'data' axis (decode slots /
    request batch).  With one device this is the degenerate (1, 1) mesh —
    the sharded engine code path with single-device placement, which the
    parity tests use to keep the sharded runtime exercised in 1-CPU CI.
    """
    n = len(jax.devices())
    model_parallel = max(1, min(model_parallel, n))
    while n % model_parallel:
        model_parallel -= 1
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def data_shards(mesh) -> int:
    """Number of batch shards = product of pod/data axis sizes."""
    n = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n
