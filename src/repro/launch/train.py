"""Training driver: --arch config, sharded train loop, checkpoint/restart.

Fault tolerance:
  * atomic sharded checkpoints every --ckpt-every steps (training/checkpoint)
  * --resume restores the latest checkpoint; the data pipeline is a pure
    function of step, so the token stream replays exactly
  * restore is mesh-agnostic: a run killed on the multi-pod mesh resumes on
    whatever ``elastic_mesh()`` finds alive

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 10
"""

from __future__ import annotations

import argparse
import time

import jax

from repro import configs as C
from repro.data.pipeline import SyntheticLMPipeline, device_put_batch
from repro.launch.mesh import data_shards, elastic_mesh
from repro.models import transformer as T
from repro.training import checkpoint as ck
from repro.training import optimizer as opt
from repro.training import train as TR


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--two-hop", action="store_true",
                    help="include 2-hop facts (cloud-tier curriculum)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", action="store_true",
                    help="shard over an elastic mesh of all local devices")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch) if args.smoke else C.get_config(args.arch)
    mesh = elastic_mesh() if args.mesh else None
    ocfg = opt.AdamWConfig(lr=args.lr, total_steps=args.steps,
                           warmup_steps=max(args.steps // 10, 1))
    step_fn = TR.build_train_step(cfg, ocfg, mesh,
                                  microbatches=args.microbatches,
                                  moe_groups=data_shards(mesh) if mesh else 1)

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    state = opt.init(params)
    start = 0
    if args.ckpt_dir and args.resume:
        latest = ck.latest_step(args.ckpt_dir)
        if latest is not None:
            abs_tree = {"params": T.abstract_params(cfg),
                        "opt": opt.abstract_state(T.abstract_params(cfg))}
            sh = None
            if mesh is not None:
                sh = {"params": TR.param_shardings(cfg, mesh),
                      "opt": TR.opt_shardings(cfg, mesh)}
            tree, extra = ck.restore(args.ckpt_dir, latest, abs_tree, sh)
            params, state = tree["params"], tree["opt"]
            start = int(extra["step"]) if "step" in extra else latest
            print(f"[train] resumed from step {start}")

    pipe = SyntheticLMPipeline(args.batch, args.seq, two_hop=args.two_hop,
                               seed=args.seed)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = device_put_batch(pipe.get_batch(step), mesh)
        params, state, metrics = step_fn(params, state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"[train] step {step} loss {loss:.4f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ck.save(args.ckpt_dir, step + 1,
                    {"params": params, "opt": state}, extra={"step": step + 1})
            print(f"[train] checkpoint @ {step + 1}")
    if args.ckpt_dir:
        ck.save(args.ckpt_dir, args.steps, {"params": params, "opt": state},
                extra={"step": args.steps})
    print(f"[train] done: final loss {float(metrics['loss']):.4f}")
    return params


if __name__ == "__main__":
    main()
