import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
compiles, fits, and report its roofline inputs.

For each cell we compile:
  1. the FULL production step (scan-over-layers) -> memory_analysis (peak
     per-device bytes) + the lower/compile proof itself;
  2. two UNROLLED slice models (prefix + 1x / 2x pattern periods) ->
     linearly extrapolated per-device FLOPs / bytes / collective-bytes.
     (XLA's cost analysis counts a `while` body ONCE regardless of trip
     count, so scanned programs must be slice-corrected — measured, see
     EXPERIMENTS.md §Dry-run methodology.)

Collective bytes are parsed from the post-SPMD optimized HLO; per-device
link traffic uses ring-algorithm factors (AR 2(G-1)/G, AG/RS/A2A (G-1)/G of
the full payload, CP 1x).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out-dir experiments/dryrun]
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.distributed import sharding as sh
from repro.launch.mesh import data_shards, make_production_mesh
from repro.models import transformer as T
from repro.training import optimizer as opt
from repro.training import train as TR

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective traffic (bytes) by op type, ring-model factors."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes = n * DTYPE_BYTES[dt]
        g = None
        gm = _GROUP_RE.search(line)
        if gm:
            g = int(gm.group(2))            # [n_groups, group_size]
        else:
            gl = _GROUP_LIST_RE.search(line)
            if gl:
                g = len(gl.group(1).split(","))
        g = g or 2
        if op == "all-reduce":
            traffic = 2 * (g - 1) / g * nbytes
        elif op == "all-gather":
            traffic = (g - 1) / g * nbytes          # result is full payload
        elif op == "reduce-scatter":
            traffic = (g - 1) * nbytes              # operand = result * g
        elif op == "all-to-all":
            traffic = (g - 1) / g * nbytes
        else:                                       # collective-permute
            traffic = nbytes
        out[op] += traffic
        counts[op] += 1
    out["counts"] = counts
    return out


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def _abstract_batch(cfg, shape):
    return C.input_specs(cfg, shape)


ACT_BUDGET_BYTES = 8e9     # per-device live-activation target (v5e: 16 GB HBM)


def train_microbatches(cfg, shape, mesh) -> int:
    """Grad-accumulation factor so scanned-layer residuals fit HBM.

    The layer scan saves its carry (B_loc, S, D) per step for backward:
    L * B_loc * S * D * 2 bytes.  Choose the smallest power-of-two split
    keeping that (plus the logits block) under ACT_BUDGET_BYTES.
    """
    dp = data_shards(mesh)
    b_loc = max(shape.global_batch // dp, 1)
    resid = cfg.num_layers * b_loc * shape.seq_len * cfg.d_model * 2
    tp = mesh.shape.get("model", 1)
    logits = b_loc * shape.seq_len * (cfg.vocab_size // tp) * 6
    mb = 1
    while (resid + logits) / mb > ACT_BUDGET_BYTES and mb < b_loc:
        mb *= 2
    return mb


def build_cell(cfg, shape, mesh, rules=None, force_mb: int | None = None):
    """Returns (jitted_fn, example_args) for one cell."""
    rules = rules or sh.DEFAULT_RULES
    B = shape.global_batch
    tokens_total = B * (1 if shape.kind == "decode" else shape.seq_len)
    groups = data_shards(mesh)
    while tokens_total % groups:
        groups //= 2        # MoE dispatch groups must divide the token count

    ps = TR.param_shardings(cfg, mesh, rules)
    abs_p = T.abstract_params(cfg)

    if shape.kind == "train":
        mb = force_mb or train_microbatches(cfg, shape, mesh)
        step = TR.build_train_step(cfg, opt.AdamWConfig(), mesh, rules=rules,
                                   moe_groups=groups, microbatches=mb)
        abs_o = opt.abstract_state(abs_p)
        batch = _abstract_batch(cfg, shape)
        return step, (abs_p, abs_o, batch)

    if shape.kind == "prefill":
        batch = _abstract_batch(cfg, shape)

        def fwd(params, b):
            logits, _ = T.forward(params, cfg, b, moe_groups=groups,
                                  mesh=mesh, rules=rules)
            return logits
        bs = TR.batch_shardings(batch, mesh)
        return jax.jit(fwd, in_shardings=(ps, bs)), (abs_p, batch)

    # decode: one new token against a seq_len-deep cache
    spec = _abstract_batch(cfg, shape)
    cache_sds = spec["cache"]
    cax = T.cache_axes(cfg)
    cache_specs = sh.tree_specs(cache_sds, cax, mesh, rules.act_rules)
    cache_sh = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), cache_specs)
    tok_sh = TR.batch_shardings({"t": spec["tokens"]}, mesh)["t"]
    idx_sh = TR.batch_shardings({"t": spec["index"]}, mesh)["t"]

    def serve_step(params, tokens, cache, index):
        # decode MoE dispatch is per-token exact top-k (no dispatch groups)
        logits, cache = T.decode_step(params, cfg, tokens, cache, index,
                                      mesh=mesh, rules=rules)
        return logits, cache

    fn = jax.jit(serve_step,
                 in_shardings=(ps, tok_sh, cache_sh, idx_sh),
                 out_shardings=(None, cache_sh),
                 donate_argnums=(2,))
    return fn, (abs_p, spec["tokens"], cache_sds, spec["index"])


def compile_cell(cfg, shape, mesh, rules=None, force_mb: int | None = None):
    fn, args = build_cell(cfg, shape, mesh, rules, force_mb=force_mb)
    t0 = time.time()
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    dt = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca    # jax<0.5 returns [dict]
    coll = parse_collectives(compiled.as_text())
    return {
        "compile_s": round(dt, 2),
        "flops_per_device": ca.get("flops", 0.0),
        "bytes_per_device": ca.get("bytes accessed", 0.0),
        "memory": {
            "argument": ma.argument_size_in_bytes,
            "output": ma.output_size_in_bytes,
            "temp": ma.temp_size_in_bytes,
            "alias": ma.alias_size_in_bytes,
            "peak_est": ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes,
        },
        "collectives": coll,
    }


def _slice_configs(cfg):
    """(slice_a_cfg, slice_b_cfg, repeats_R): A has prefix+period+tail
    layers, B has one extra period; full = A + (R-1) * (B - A)."""
    stages = cfg.stage_plan()
    body = max(stages, key=lambda s: s.repeat)
    period = len(body.blocks)
    other = sum(len(s.blocks) * s.repeat for s in stages) \
        - period * body.repeat
    la = other + period
    lb = other + 2 * period
    a = dataclasses.replace(cfg, num_layers=la, scan_layers=False)
    b = dataclasses.replace(cfg, num_layers=lb, scan_layers=False)
    return a, b, body.repeat


def corrected_costs(cfg, shape, mesh, rules=None) -> dict:
    """Slice-extrapolated per-device flops/bytes/collectives for the cell.

    Slices compile with microbatches=1: the grad-accumulation scan is a
    `while` loop whose body XLA's cost analysis counts once, so slices with
    different mb would break the linear extrapolation (measured: command-r
    train_4k showed 6ND/HLO = 20x before this fix).  mb does not change the
    per-token flops/bytes, only live memory — which comes from the full
    compile.
    """
    ca_cfg, cb_cfg, R = _slice_configs(cfg)
    ra = compile_cell(ca_cfg, shape, mesh, rules, force_mb=1)
    rb = compile_cell(cb_cfg, shape, mesh, rules, force_mb=1)

    def lin(pa, pb):
        return pa + (R - 1) * max(pb - pa, 0.0)

    coll = {}
    for k in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute"):
        coll[k] = lin(ra["collectives"][k], rb["collectives"][k])
    return {
        "flops_per_device": lin(ra["flops_per_device"], rb["flops_per_device"]),
        "bytes_per_device": lin(ra["bytes_per_device"], rb["bytes_per_device"]),
        "collective_bytes_per_device": sum(coll.values()),
        "collectives": coll,
        "slice_layers": (ca_cfg.num_layers, cb_cfg.num_layers, R),
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             skip_existing: bool = True, variant: str = "",
             rules_name: str = "default", moe_impl: str | None = None,
             act_budget: float | None = None,
             serve_dtype: str | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{variant}" if variant else ""
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    cfg = C.get_config(arch)
    if moe_impl:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    if serve_dtype == "f8":
        cfg = dataclasses.replace(cfg, dtype=jnp.float8_e4m3fn,
                                  compute_dtype=jnp.bfloat16)
    shape = C.SHAPES[shape_name]
    skip = C.applicability(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "variant": variant, "rules": rules_name,
           "model_params": cfg.num_params(),
           "active_params": cfg.active_params()}
    if skip:
        rec["skipped"] = skip
    else:
        global ACT_BUDGET_BYTES
        old_budget = ACT_BUDGET_BYTES
        if act_budget:
            ACT_BUDGET_BYTES = act_budget
        rules = sh.RULE_SETS[rules_name]
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        try:
            rec["full"] = compile_cell(cfg, shape, mesh, rules)
            rec["corrected"] = corrected_costs(cfg, shape, mesh, rules)
            rec["ok"] = True
        except Exception as e:  # noqa: BLE001 - record failure for the report
            rec["ok"] = False
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-3000:]
        finally:
            ACT_BUDGET_BYTES = old_budget
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec.get("skipped") and "SKIP" or (rec.get("ok") and "OK" or "FAIL")
    print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}{suffix}: {status}",
          flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="",
                    help="suffix for hillclimb artifacts")
    ap.add_argument("--rules", default="default",
                    choices=["default", "sp", "serve"])
    ap.add_argument("--moe-impl", default=None, choices=["sort", "cumsum"])
    ap.add_argument("--act-budget", type=float, default=None)
    ap.add_argument("--serve-dtype", default=None, choices=["f8"])
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = (C.cells(include_skipped=True) if args.all
             else [(args.arch, args.shape, None)])
    n_ok = n_fail = 0
    for arch, shape_name, _ in cells:
        for mk in meshes:
            rec = run_cell(arch, shape_name, mk, args.out_dir,
                           skip_existing=not args.force,
                           variant=args.variant, rules_name=args.rules,
                           moe_impl=args.moe_impl,
                           act_budget=args.act_budget,
                           serve_dtype=args.serve_dtype)
            if rec.get("ok") or rec.get("skipped"):
                n_ok += 1
            else:
                n_fail += 1
    print(f"[dryrun] done: {n_ok} ok/skip, {n_fail} failed", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
