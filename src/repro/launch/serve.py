"""Serving driver: stand up the full SWARM-LLM gateway on trained smokes.

Trains the three-tier swarm (probe + 2 peers, 1-hop curriculum), the cloud
FM tier (1+2-hop curriculum) and the safety classifier, then routes the
paper's 50-query study workload and prints Table III/IV/V-style metrics.

  PYTHONPATH=src python -m repro.launch.serve --train-steps 150
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs as C
from repro.core import safety as safety_lib
from repro.core.cost_model import LatencyParams
from repro.core.router import RouterConfig
from repro.core.uncertainty import UncertaintyConfig
from repro.data.pipeline import SyntheticLMPipeline
from repro.data.workload import FactWorld
from repro.models import transformer as T
from repro.serving.engine import InferenceEngine
from repro.serving.gateway import Gateway, run_cloud_only, run_edge_only
from repro.serving.simulator import NetworkSimulator, SimConfig
from repro.serving.swarm import SwarmExecutor
from repro.training import optimizer as opt
from repro.training import train as TR


def train_lm(arch: str, steps: int, *, two_hop: bool, seed: int,
             batch: int = 16, seq: int = 64, lr: float = 1e-2,
             num_layers: int | None = None, world: FactWorld | None = None):
    import dataclasses
    cfg = C.get_smoke(arch)
    cfg = dataclasses.replace(cfg, vocab_size=512)
    if num_layers is not None:
        cfg = dataclasses.replace(cfg, num_layers=num_layers)
    ocfg = opt.AdamWConfig(lr=lr, total_steps=steps,
                           warmup_steps=max(steps // 10, 1), weight_decay=0.0)
    step_fn = TR.build_train_step(cfg, ocfg, None)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    state = opt.init(params)
    pipe = SyntheticLMPipeline(batch, seq, two_hop=two_hop, seed=seed,
                               world=world)
    for step in range(steps):
        b = {k: jax.numpy.asarray(v) for k, v in pipe.get_batch(step).items()}
        params, state, m = step_fn(params, state, b)
    print(f"[serve] trained {arch} ({'2-hop' if two_hop else '1-hop'}) "
          f"final loss {float(m['loss']):.3f}")
    return cfg, params


def train_safety(steps: int = 150, seed: int = 5):
    from repro.training import optimizer as opt_lib
    cfg = safety_lib.classifier_config(vocab_size=512)
    params = safety_lib.init_safety(cfg, jax.random.PRNGKey(seed))
    state = opt_lib.init(params)
    trainer = safety_lib.make_trainer(cfg, lr=1e-2, steps=steps)
    world = FactWorld()
    for step in range(steps):
        # length 6 matches the study prompts: a single risk marker in a
        # short query must score below sigma (borderline cases, Table V SER)
        toks, labels = world.safety_training_batch(32, 6, step)
        params, state, loss = trainer(params, state, jax.numpy.asarray(toks),
                                      jax.numpy.asarray(labels))
    print(f"[serve] safety classifier BCE {float(loss):.3f}")
    return cfg, params


def calibrate_thresholds(probe: InferenceEngine, world: FactWorld,
                         base: RouterConfig, n: int = 24, max_new: int = 8
                         ) -> RouterConfig:
    """Fit τ_low/τ_high from the probe's U distribution on held-out queries
    (the paper tuned its 'final experiments' thresholds the same way,
    Sec. V-C).  τ_high at the 72.5th percentile targets the paper's ~28%
    escalation; τ_low at the 40th keeps the swarm path exercised."""
    import dataclasses as dc
    from repro.serving.swarm import pad_prompts
    qs = world.easy_queries(n, seed=101) + world.hard_queries(n, seed=102)
    res = probe.generate(pad_prompts([q["prompt"] for q in qs]), max_new)
    u = np.sort(res["u"])
    tau_low = float(np.quantile(u, 0.40))
    tau_high = float(np.quantile(u, 0.90))
    return dc.replace(base, tau_low=tau_low, tau_high=tau_high)


def build_gateway(train_steps: int = 150, quorum: int | None = None,
                  sim_cfg: SimConfig | None = None,
                  router_cfg: RouterConfig | None = None,
                  budget_total: float = 1.0, seed: int = 0,
                  world: FactWorld | None = None,
                  calibrate: bool = True, mesh=None,
                  engine_kw: dict | None = None):
    """Construct the full three-tier system (returns gateway + baselines).

    ``mesh`` (a ``launch.mesh.serving_mesh()`` (data, model) mesh) places
    every tier's engine on the mesh: greedy routing decisions and tokens
    are identical to the single-device gateway, but prefill/decode run
    SPMD-partitioned (see docs/SHARDING.md).

    ``engine_kw`` is forwarded to every tier's :class:`InferenceEngine`
    (e.g. ``paged=True``, ``attn_decode_impl=...``,
    ``compilation_cache_dir=...`` — see ``main()``'s flags).
    """
    engine_kw = engine_kw or {}
    # a compact fact world so the smoke-scale tiers genuinely memorise it
    world = world or FactWorld(n_ent=16, n_rel=6)
    ucfg = UncertaintyConfig(alpha=1.0, mode="distribution")
    # Tier-1 edge swarm: three heterogeneous SLMs (1-hop curriculum).
    # The probe (weakest member, paper's TinyLlama analogue) trains longest
    # to land near the paper's 0.45-easy edge tier; peers are stronger.
    probe_cfg, probe_p = train_lm("smollm-135m", 3 * train_steps,
                                  two_hop=False, seed=seed, world=world)
    e2_cfg, e2_p = train_lm("swarm-edge-1b", train_steps,
                            two_hop=False, seed=seed + 1, world=world)
    e3_cfg, e3_p = train_lm("qwen1.5-110b", train_steps,
                            two_hop=False, seed=seed + 2, world=world)
    # Tier-2 cloud FM: deeper + 2-hop curriculum + more steps
    fm_cfg, fm_p = train_lm("llama3-8b", int(2.25 * train_steps),
                            two_hop=True, seed=seed + 3, num_layers=4,
                            world=world)

    probe = InferenceEngine("probe-smollm", probe_cfg, probe_p, ucfg,
                            mesh=mesh, **engine_kw)
    peers = [probe,
             InferenceEngine("edge-1b", e2_cfg, e2_p, ucfg, mesh=mesh,
                             **engine_kw),
             InferenceEngine("edge-qwen", e3_cfg, e3_p, ucfg, mesh=mesh,
                             **engine_kw)]
    cloud = InferenceEngine("cloud-fm", fm_cfg, fm_p, ucfg, mesh=mesh,
                            **engine_kw)
    scfg, sparams = train_safety()

    rcfg = router_cfg or RouterConfig(tau_low=0.08, tau_high=0.22, sigma=0.7,
                                      peers_k=2, gamma=0.3, l_max=4.0)
    if calibrate and router_cfg is None:
        rcfg = calibrate_thresholds(probe, world, rcfg)
        print(f"[serve] calibrated tau_low={rcfg.tau_low:.3f} "
              f"tau_high={rcfg.tau_high:.3f}")

    sim = NetworkSimulator(sim_cfg or SimConfig(), LatencyParams(),
                           n_members=len(peers))
    from repro.data.workload import FACT_IS
    gw = Gateway(
        probe=probe, swarm=SwarmExecutor(peers, stop_token=FACT_IS),
        cloud=cloud,
        safety_params=sparams, safety_cfg=scfg, router_cfg=rcfg,
        sim=sim, budget_total=budget_total, quorum=quorum)
    return gw, probe, cloud, world


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--quorum", type=int, default=None)
    ap.add_argument("--budget", type=float, default=1.0)
    ap.add_argument("--model-parallel", type=int, default=0,
                    help="serve on a (data, model) mesh over the live "
                         "devices with this much tensor parallelism "
                         "(0 = single-device engines)")
    ap.add_argument("--paged", action="store_true",
                    help="serve every tier off the paged block-pool cache "
                         "(docs/RUNTIME.md 'Paged caches & prefix sharing')")
    ap.add_argument("--attn-decode-impl", choices=("kernel", "gather"),
                    default=None,
                    help="paged decode-attention impl (implies --paged); "
                         "default: measured-best per backend — see "
                         "docs/RUNTIME.md 'Kernel-first decode'")
    ap.add_argument("--cache-quant", choices=("int8", "fp8"), default=None,
                    help="store paged KV blocks quantized with per-row f32 "
                         "scales (implies --paged); ~1.9x the sessions per "
                         "pool byte — see docs/RUNTIME.md 'Quantized caches'")
    ap.add_argument("--compilation-cache-dir", default=None,
                    help="persistent XLA compilation cache directory: a "
                         "relaunched gateway skips every already-seen jit")
    args = ap.parse_args()

    mesh = None
    if args.model_parallel > 0:
        from repro.launch.mesh import serving_mesh
        mesh = serving_mesh(model_parallel=args.model_parallel)
        print(f"[serve] mesh {dict(mesh.shape)}")
    engine_kw = {}
    if (args.paged or args.attn_decode_impl is not None
            or args.cache_quant is not None):
        # the study workload batches ~50 queries through each tier, well
        # past the default pool sizing (16 full-length sessions) — give
        # the gateway engines headroom for the full workload batch
        engine_kw.update(paged=True, pool_blocks=1024,
                         attn_decode_impl=args.attn_decode_impl)
    if args.cache_quant is not None:
        engine_kw["cache_quant"] = args.cache_quant
    if args.compilation_cache_dir is not None:
        engine_kw["compilation_cache_dir"] = args.compilation_cache_dir
    gw, probe, cloud, world = build_gateway(args.train_steps, args.quorum,
                                            budget_total=args.budget,
                                            mesh=mesh, engine_kw=engine_kw)
    queries = world.study_workload()

    log = gw.answer_batch(queries)
    # baselines graded on the SAME answer normalisation as the gateway
    stop = gw.swarm.stop_token
    edge = run_edge_only(queries, probe, gw.sim, stop_token=stop)
    cl = run_cloud_only(queries, cloud, gw.sim, stop_token=stop)

    print("\n=== Table III: latency & cloud usage ===")
    for name, lg in [("Edge-Only", edge), ("Cloud-Only", cl),
                     ("SWARM-LLM", log)]:
        print(f"{name:12s} mean {lg.latency.mean():5.2f}s  "
              f"p95 {np.percentile(lg.latency, 95):5.2f}s  "
              f"cloud {lg.cloud_usage()*100:5.1f}%")
    print("\n=== Table IV: accuracy ===")
    for name, lg in [("Edge-Only", edge), ("Cloud-Only", cl),
                     ("SWARM-LLM", log)]:
        print(f"{name:12s} overall {lg.accuracy():.3f}  "
              f"easy {lg.accuracy('easy'):.3f}  hard {lg.accuracy('hard'):.3f}")
    print("\n=== Table V: privacy (normalised to cloud-only) ===")
    pm = log.privacy()
    print(f"SWARM-LLM  CER {float(pm.cer):.3f}  TER {float(pm.ter):.3f}  "
          f"SER {float(pm.ser):.3f}")


if __name__ == "__main__":
    main()
