"""AdamW in pure JAX, ZeRO-style: optimizer state inherits param sharding.

Master weights + first/second moments are f32 regardless of param dtype
(bf16 params at 110B scale); update math runs in f32 and casts back.  The
state tree is parallel to the param tree, so the same logical-axis sharding
rules shard it (= ZeRO-1/2 when params are FSDP-sharded).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: Array
    master: Any   # f32 master weights
    m: Any
    v: Any


def init(params: Any) -> OptState:
    # copy=True: astype on an already-f32 param would alias it, and aliased
    # buffers break donation (donated twice) in the train step
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    master=jax.tree.map(f32, params),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def abstract_state(abstract_params: Any) -> OptState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                    master=jax.tree.map(f32, abstract_params),
                    m=jax.tree.map(f32, abstract_params),
                    v=jax.tree.map(f32, abstract_params))


def schedule(step: Array, cfg: AdamWConfig) -> Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def apply(grads: Any, params: Any, state: OptState, cfg: AdamWConfig
          ) -> tuple[Any, OptState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, master, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / b1c, v / b2c
        master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                + cfg.weight_decay * master)
        return master, m, v

    out = jax.tree.map(upd, grads, state.master, state.m, state.v)
    master = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda ma, p: ma.astype(p.dtype), master, params)
    new_state = OptState(step=step, master=master, m=m, v=v)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
