"""Sharded, atomic, mesh-agnostic checkpointing (fault-tolerance substrate).

Format: <dir>/step_<n>/
    manifest.json    — tree structure, global shapes/dtypes, step, extra
    shard_<i>.npz    — this process's addressable shards (leaf-path keyed)

Writes go to <dir>/tmp_<n> then os.replace -> atomic publish; a LATEST file
is updated last, so a crash mid-save can never corrupt the recoverable
state.  Restore rebuilds global arrays from per-shard callbacks against the
*current* mesh/shardings, so a checkpoint taken on a 2x16x16 mesh restores
onto 16x16 (elastic re-mesh after node loss — DESIGN.md §5).
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{SEP}"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "shape"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{SEP}"))
    else:
        out[prefix.rstrip(SEP)] = tree
    return out


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None,
         keep: int = 3) -> str:
    """Write one checkpoint atomically; prune old ones. Returns final path."""
    flat = _flatten(tree)
    treedef = jax.tree.structure(tree)
    tmp = os.path.join(ckpt_dir, f"tmp_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "extra": extra or {},
                "treedef": str(treedef),
                "leaves": {}}
    shard_payload = {}
    for path, leaf in flat.items():
        arr = leaf
        manifest["leaves"][path] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype)}
        if hasattr(arr, "addressable_shards"):
            for sh in arr.addressable_shards:
                key = f"{path}@{'_'.join(map(str, _index_key(sh.index, arr.shape)))}"
                shard_payload[key] = _to_savable(np.asarray(sh.data))
        else:
            shard_payload[f"{path}@full"] = _to_savable(np.asarray(arr))
    pid = jax.process_index()
    np.savez(os.path.join(tmp, f"shard_{pid}.npz"), **shard_payload)
    if pid == 0:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic publish
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    _prune(ckpt_dir, keep)
    return final


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz can't hold ml_dtypes (bfloat16 etc.) — store as a uint view; the
    manifest records the logical dtype for restore."""
    if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
        return arr.view(np.uint16) if arr.dtype.itemsize == 2 \
            else arr.view(np.uint8)
    return arr


def _index_key(index, shape):
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else sl.start
        stop = dim if sl.stop is None else sl.stop
        out.extend([start, stop])
    return out


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, step: int, abstract_tree: Any,
            shardings: Any | None = None) -> tuple[Any, dict]:
    """Rebuild the tree on the current mesh. abstract_tree gives structure."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    # load all shard files (single- or multi-host written)
    payload: dict[str, np.ndarray] = {}
    for fn in os.listdir(d):
        if fn.startswith("shard_") and fn.endswith(".npz"):
            with np.load(os.path.join(d, fn)) as z:
                for k in z.files:
                    payload[k] = z[k]

    def assemble(path: str, spec) -> np.ndarray:
        shape = tuple(manifest["leaves"][path]["shape"])
        dtype = manifest["leaves"][path]["dtype"]
        if dtype == "bfloat16":
            import ml_dtypes
            np_dtype = np.dtype(ml_dtypes.bfloat16)
        else:
            np_dtype = np.dtype(dtype)

        def decode(a: np.ndarray) -> np.ndarray:
            return a.view(np_dtype) if a.dtype != np_dtype else a

        full = np.zeros(shape, np_dtype)
        for key, arr in payload.items():
            p, _, idx = key.rpartition("@")
            if p != path:
                continue
            if idx in ("full", ""):      # "" = 0-d array shard
                return decode(arr).reshape(shape)
            nums = list(map(int, idx.split("_")))
            sls = tuple(slice(nums[2 * i], nums[2 * i + 1])
                        for i in range(len(nums) // 2))
            full[sls] = decode(arr)
        return full

    flat_abs = _flatten(abstract_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    leaves_out = {}
    for path, sds in flat_abs.items():
        host = assemble(path, sds)
        sh = flat_shard.get(path)
        if sh is not None:
            leaves_out[path] = jax.make_array_from_callback(
                host.shape, sh, lambda idx, h=host: h[idx])
        else:
            leaves_out[path] = jax.numpy.asarray(host)

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}{SEP}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)) and not hasattr(tree, "shape"):
            vals = [rebuild(v, f"{prefix}{i}{SEP}") for i, v in enumerate(tree)]
            return type(tree)(vals) if not hasattr(tree, "_fields") \
                else type(tree)(*vals)
        return leaves_out[prefix.rstrip(SEP)]

    return rebuild(abstract_tree), manifest["extra"]
