"""Gradient compression for the cross-pod (DCN/WAN-class) hop.

Pods are the paper's "edge sites": intra-pod links are fast ICI, while the
pod axis crosses slower links — exactly where SWARM-LLM's cost model charges
c_comm per byte (Eq. 8).  We compress the cross-pod gradient all-reduce to
int8 with per-tensor scale and *error feedback* (the quantisation residual
is carried to the next step), which preserves convergence (Karimireddy et
al., 2019) while cutting pod-link bytes 4x vs f32 / 2x vs bf16.

``compressed_psum`` is used inside a ``shard_map`` over the 'pod' axis (see
launch/train.py --grad-compression); quantise/dequantise are pure and unit-
tested standalone.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


def quantise_int8(x: Array) -> tuple[Array, Array]:
    """f32/bf16 -> (int8, scale). Symmetric per-tensor."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantise_int8(q: Array, scale: Array, dtype=jnp.float32) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_with_feedback(g: Array, err: Array) -> tuple[Array, Array, Array]:
    """Returns (int8 payload, scale, new error residual)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantise_int8(corrected)
    deq = dequantise_int8(q, scale)
    return q, scale, corrected - deq


def compressed_psum(g: Array, err: Array, axis_name: str
                    ) -> tuple[Array, Array]:
    """int8 error-feedback all-reduce over `axis_name` (inside shard_map).

    Each participant quantises its shard contribution; the sum of int8
    payloads is exact in int32, then a single dequant by the max scale.
    Returns (reduced grads f32, new error residual).
    """
    q, scale, new_err = compress_with_feedback(g, err)
    total = jax.lax.psum(q.astype(jnp.int32) * 1, axis_name)
    # conservative shared scale: max over participants keeps the sum bounded
    scale_max = jax.lax.pmax(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = total.astype(jnp.float32) * scale_max / n
    return mean, new_err


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
