"""Train-step factory: FSDP x TP sharded, grad-accumulated, fault-tolerant.

``build_train_step`` returns a jit-compiled (params, opt_state, batch) ->
(params, opt_state, metrics) function with:

  * in/out shardings derived from the logical-axis rules (ZeRO: opt state
    shards like params),
  * optional microbatch gradient accumulation (lax.scan over microbatches —
    the per-microbatch gradient all-reduce overlaps the next microbatch's
    compute under XLA's latency-hiding scheduler),
  * donated params/opt-state buffers (no double residency).

The driver loop (launch/train.py) adds checkpoint/restart and deterministic
data replay; elastic re-mesh is restore-time (checkpoint.py).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as sh
from repro.models import transformer as T
from repro.models.common import ModelConfig
from repro.training import optimizer as opt


def param_shardings(cfg: ModelConfig, mesh: Mesh,
                    rules: sh.ShardingRules | None = None):
    rules = rules or sh.DEFAULT_RULES
    abs_params = T.abstract_params(cfg)
    axes = T.param_axes(cfg)
    return sh.tree_shardings(abs_params, axes, mesh, rules)


def opt_shardings(cfg: ModelConfig, mesh: Mesh,
                  rules: sh.ShardingRules | None = None):
    ps = param_shardings(cfg, mesh, rules)
    return opt.OptState(
        step=NamedSharding(mesh, P()),
        master=ps, m=ps, v=ps)


def batch_shardings(batch_spec: dict, mesh: Mesh,
                    rules: sh.ShardingRules | None = None):
    rules = rules or sh.DEFAULT_RULES

    def one(x):
        logical = ["act_batch"] + [None] * (len(x.shape) - 1)
        return NamedSharding(
            mesh, sh.spec_for(x.shape, logical, mesh, rules.act_rules))
    return jax.tree.map(one, batch_spec)


def build_train_step(cfg: ModelConfig, ocfg: opt.AdamWConfig, mesh: Mesh | None,
                     *, rules: sh.ShardingRules | None = None,
                     microbatches: int = 1, moe_groups: int = 1,
                     donate: bool = True):
    rules = rules or sh.DEFAULT_RULES

    def loss_fn(params, batch):
        return T.loss_fn(params, cfg, batch, moe_groups=moe_groups,
                         mesh=mesh, rules=rules)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def mb(carry, mbatch):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m
            split = jax.tree.map(
                lambda x: x.reshape((microbatches, -1) + x.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, loss), metrics = jax.lax.scan(mb, (zeros, 0.0), split)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
        params, opt_state, om = opt.apply(grads, params, opt_state, ocfg)
        metrics = dict(metrics, **om, loss=loss)
        return params, opt_state, metrics

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())

    ps = param_shardings(cfg, mesh, rules)
    os_ = opt_shardings(cfg, mesh, rules)
    return jax.jit(
        step,
        in_shardings=(ps, os_, None),
        out_shardings=(ps, os_, None),
        donate_argnums=(0, 1) if donate else (),
    )


def build_eval_step(cfg: ModelConfig, mesh: Mesh | None = None,
                    rules: sh.ShardingRules | None = None,
                    moe_groups: int = 1):
    def step(params, batch):
        loss, metrics = T.loss_fn(params, cfg, batch, moe_groups=moe_groups,
                                  mesh=mesh, rules=rules)
        return metrics
    return jax.jit(step)
